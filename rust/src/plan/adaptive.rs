//! Adaptive re-planning: the runtime feedback loop from executor to
//! planner (Spark-AQE-style, specialised to the paper's bloom math).
//!
//! The static planner commits every edge's probe order, strategy and ε
//! up front, from HLL catalog estimates priced with the §7 cost model.
//! Both inputs can be wrong at run time, and each failure has its own
//! trigger here:
//!
//! **Cardinality trigger.**  After edge `i` finishes, the executor
//! compares the edge's estimated survivor count `Ê` against the measured
//! survivor count `M` (the contracted stream length).  `Ê` is the
//! planner's `matched_rows` **rescaled to the stream the edge actually
//! probed** ([`expected_survivors`]) — i.e. the planner's match
//! *fraction* applied to the measured probe, so the check judges this
//! edge's own selectivity estimate, not upstream contraction that
//! earlier checks already judged.  The estimate is *consistent* with the
//! sketch error model when the relative error `|M − Ê| / max(Ê, 1)` is
//! within the HLL 3σ bound; anything larger cannot be explained by
//! sketch noise and means the catalog's picture of the remaining
//! workload is wrong too.  [`should_replan`] fires exactly then — unless
//! the **absolute residual** `|M − Ê|` is below the spec's row floor
//! ([`DEFAULT_ROW_FLOOR`]): at single-digit residuals the relative bound
//! is meaningless, and one row of noise must not re-plan a cheap tail.
//!
//! **Strategy-regret trigger** ([`regret_flip`]).  Estimates can be
//! exact while the *cost constants* are wrong (a stale or contaminated
//! calibration store, a mis-modelled cluster).  Every executed bloom
//! edge reports its measured §7 stage seconds next to the uncalibrated
//! model's prediction on the same measured workload; the run-local fit
//! of those pairs (the same through-origin regression the persistent
//! [`super::costing::CostCalibration`] uses, trusted from one in-run
//! sample) re-prices the not-yet-executed tail.  When some remaining
//! edge's assigned strategy is no longer within [`REGRET_MARGIN`] of the
//! re-priced cheapest — the cheapest-strategy ranking would have flipped
//! — the tail is re-planned with the measured factors.  Only
//! [`ReplanPolicy::Regret`] arms this trigger; cardinality-only
//! [`ReplanPolicy::Adaptive`] keeps re-pricing with whatever the planner
//! trusted, which is exactly why it cannot win on mispriced-constant
//! workloads (`benches/fig9_regret.rs`).
//!
//! **Mid-build ε re-size** ([`resize_epsilon`]).  Edge execution is
//! split into build / broadcast / probe phases
//! ([`crate::joins::bloom_cascade::BloomCascadeJoin::execute_with_resize`])
//! with a re-plan point between build and broadcast — the last moment
//! before the filter's size is shipped.  Under the regret policy the
//! executor re-solves ε* there from what the build phase measured (the
//! approximate build-side count, the known probe stream length, the
//! run-local stage factors) and rebuilds the filter when the corrected ε
//! pays for the rebuild even if the whole §7 stage 1 is paid a second
//! time.  The payback condition makes this a one-direction correction: a
//! too-loose filter is worth rebuilding tighter (the false-positive
//! shuffle is still ahead), while a too-tight filter's cost is already
//! sunk and re-sizing can never pay.
//!
//! **Re-plan.**  On a trigger, [`replan_remaining`] re-runs the planning
//! pipeline for the not-yet-executed star tail against the *measured*
//! residual (re-rank, re-derive workloads, re-solve every bloom ε* with
//! `model::newton`); [`replan_chain_tail`] does the same for chain
//! topologies by rescaling the tail's propagated build-side estimates by
//! the measured contraction ratio.  The whole loop is demotable to a
//! no-op with [`ReplanPolicy::Static`].
//!
//! Every executed edge also emits an [`EdgeObservation`] — the raw
//! material for the re-plan ledger, the run-local regret factors, and
//! the per-cluster [`super::costing::CostCalibration`] store that
//! refines the cost model's K/L/C constants across runs.

use crate::approx::HyperLogLog;
use crate::bloom::BloomParams;
use crate::cluster::{Cluster, ClusterConfig};
use crate::model::newton;
use crate::util::Json;

use super::catalog::{DimStats, EdgeStats};
use super::costing::{
    derive_edge_stats, edge_cost_model, exchange_cost_model, partitioned_cost_model, predict_all,
    price_edges_with, rank_dims, CostCalibration,
};
use super::{EdgeStrategy, EpsMode, PlanSpec, PlannedEdge, Relation};

/// Default absolute row floor for both triggers: the relative 3σ bound
/// is not meaningful at single-digit residuals, where one row of noise
/// would re-plan a tail that costs nothing to finish as planned.
pub const DEFAULT_ROW_FLOOR: u64 = 64;

/// Relative slack an assigned strategy is allowed over the re-priced
/// cheapest before the regret trigger fires.  The §7 model is
/// constructed, not fitted, so predictions carry structural error
/// against the staged simulation; the margin keeps near-tie edges from
/// flip-flopping on that error.
pub const REGRET_MARGIN: f64 = 0.25;

/// Smallest ε ratio (either direction) before a mid-build re-size is
/// even considered — rebuilding a filter whose target was nearly right
/// can never pay.
pub const RESIZE_RATIO: f64 = 1.5;

/// Whether the executor may re-plan the remaining edges mid-query.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ReplanPolicy {
    /// Trust the static plan end-to-end (the pre-adaptive behaviour).
    #[default]
    Static,
    /// Re-rank and re-solve the remaining edges whenever a measured
    /// survivor count falls outside the estimate's 3σ bound (and the
    /// absolute row floor).
    Adaptive,
    /// [`ReplanPolicy::Adaptive`] plus the strategy-regret trigger and
    /// the mid-build ε re-size: measured stage seconds may override the
    /// planner's cost constants, not just its cardinalities.
    Regret,
}

impl ReplanPolicy {
    pub fn name(self) -> &'static str {
        match self {
            ReplanPolicy::Static => "static",
            ReplanPolicy::Adaptive => "adaptive",
            ReplanPolicy::Regret => "regret",
        }
    }

    pub fn parse(s: &str) -> Option<ReplanPolicy> {
        match s {
            "static" => Some(ReplanPolicy::Static),
            "adaptive" => Some(ReplanPolicy::Adaptive),
            "regret" => Some(ReplanPolicy::Regret),
            _ => None,
        }
    }

    /// True for every policy that arms the cardinality trigger.
    pub fn is_adaptive(self) -> bool {
        !matches!(self, ReplanPolicy::Static)
    }
}

/// The trigger threshold: the catalog sketch's stated 3σ relative error.
/// Estimates off by more than this cannot be explained by sketch noise.
pub fn trigger_bound() -> f64 {
    HyperLogLog::relative_error_bound()
}

/// Relative error of an estimate against the measured truth.
pub fn estimate_error(estimated: u64, measured: u64) -> f64 {
    let est = estimated.max(1) as f64;
    (measured as f64 - estimated as f64).abs() / est
}

/// True when the measured survivor count is inconsistent with the
/// estimate under the sketch error `bound` — the re-plan trigger.  The
/// absolute residual must also reach `floor` rows: a relative breach on
/// a handful of rows is noise, not information.
pub fn should_replan(estimated: u64, measured: u64, bound: f64, floor: u64) -> bool {
    estimated.abs_diff(measured) >= floor.max(1) && estimate_error(estimated, measured) > bound
}

/// The planner's survivor estimate for an edge, rescaled to the stream
/// the executor actually probed: `measured_probe · (matched̂ / probê)`.
///
/// The rescaling is what makes the trigger compare like with like.  An
/// edge's planned `matched_rows` is relative to its planned probe
/// stream — in unranked (static-propagation) mode that is the full
/// scan, never the contracted stream, and even in ranked mode the
/// upstream contraction can drift *within* the bound.  Scaling the
/// estimate to the measured probe isolates **this edge's own
/// selectivity error** from upstream effects that earlier trigger
/// checks already judged.
pub fn expected_survivors(stats: &EdgeStats, measured_probe: u64) -> u64 {
    let frac = stats.matched_rows as f64 / stats.probe_rows.max(1) as f64;
    ((measured_probe as f64 * frac).round() as u64).min(measured_probe)
}

/// [`expected_survivors`] without the probe clamp.  A graph edge on a
/// non-unique key (e.g. nationkey) legitimately fans the stream *out*
/// (`matched > probe`), so its expectation must be allowed to exceed
/// the probe count — clamping would make every fan-out edge look like a
/// cardinality miss and fire spurious re-plans.
pub fn graph_expected_survivors(stats: &EdgeStats, measured_probe: u64) -> u64 {
    let frac = stats.matched_rows as f64 / stats.probe_rows.max(1) as f64;
    (measured_probe as f64 * frac).round() as u64
}

/// The fraction of probed rows a bloom filter at `eps` is expected to
/// *pass* — true matches plus the ε share of the non-matches:
/// `frac + ε·(1−frac)`.
///
/// This is the filter-level analogue of [`expected_survivors`], used by
/// the fused probe pipeline: inner edges of a fused group observe their
/// filter's pass count (false positives included) rather than a
/// join-level survivor count, because the group's single pass never
/// materialises per-edge join output.  Comparing that measurement against
/// a join-level expectation would mis-fire the cardinality trigger by
/// exactly the ε share, so the expectation is ε-inflated to match what
/// the filter can actually be wrong about.
pub fn filter_pass_fraction(stats: &EdgeStats, eps: f64) -> f64 {
    let frac = stats.matched_rows as f64 / stats.probe_rows.max(1) as f64;
    let frac = frac.clamp(0.0, 1.0);
    frac + eps.clamp(0.0, 1.0) * (1.0 - frac)
}

/// What the executor measured while running one edge.
#[derive(Clone, Debug)]
pub struct EdgeObservation {
    pub edge: String,
    pub relation: Relation,
    pub strategy: String,
    /// The ε the edge executed with (bloom edges only; the re-sized
    /// value when a mid-build re-size fired).
    pub eps: Option<f64>,
    /// Whether a mid-build re-size replaced the planned filter.  Re-sized
    /// edges pay §7 stage 1 twice, so they are excluded from the
    /// calibration fit.
    pub resized: bool,
    /// Whether the edge's filter came from the server's cross-query
    /// filter cache.  Cache-served edges skip the approx-count and build
    /// stages entirely, so their measured stage split is not the §7
    /// model's shape either — excluded from the calibration fit.
    pub cached: bool,
    /// Whether fault-recovery stages were booked while running the edge
    /// (injected faults — [`crate::cluster::faults`]).  Recovered edges
    /// pay work the §7 model does not describe (retries, rebuilds, a
    /// degraded strategy switch), so they too are excluded from the
    /// calibration fit.
    pub recovered: bool,
    pub estimated_probe_rows: u64,
    pub measured_probe_rows: u64,
    /// The planner's `matched_rows` estimate for this edge.
    pub estimated_survivors: u64,
    /// Stream rows actually surviving the edge (with multiplicity).
    pub measured_survivors: u64,
    /// Real wall seconds of the build-side stages (approx count +
    /// filter build + broadcast).
    pub build_wall_s: f64,
    /// Real wall seconds of the probe-side hot path.
    pub probe_wall_s: f64,
    /// Simulated network bytes the edge shipped.
    pub shipped_bytes: u64,
    /// The edge's total simulated seconds.
    pub sim_s: f64,
    /// §7 stage split of the measured simulated seconds.
    pub measured_stage1_s: f64,
    pub measured_stage2_s: f64,
    /// The *uncalibrated* §7 model re-evaluated on the measured workload
    /// at the executed ε (bloom edges; 0 otherwise) — the calibration
    /// store and the run-local regret factors regress measured against
    /// these to isolate constant error from estimate error.
    pub predicted_stage1_s: f64,
    pub predicted_stage2_s: f64,
}

impl EdgeObservation {
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("edge", Json::str(self.edge.clone())),
            ("relation", Json::str(self.relation.name())),
            ("strategy", Json::str(self.strategy.clone())),
            ("eps", self.eps.map_or(Json::Null, Json::num)),
            ("resized", Json::Bool(self.resized)),
            ("cached", Json::Bool(self.cached)),
            ("recovered", Json::Bool(self.recovered)),
            ("estimated_probe_rows", Json::num(self.estimated_probe_rows as f64)),
            ("measured_probe_rows", Json::num(self.measured_probe_rows as f64)),
            ("estimated_survivors", Json::num(self.estimated_survivors as f64)),
            ("measured_survivors", Json::num(self.measured_survivors as f64)),
            ("build_wall_s", Json::num(self.build_wall_s)),
            ("probe_wall_s", Json::num(self.probe_wall_s)),
            ("shipped_bytes", Json::num(self.shipped_bytes as f64)),
            ("sim_s", Json::num(self.sim_s)),
            ("measured_stage1_s", Json::num(self.measured_stage1_s)),
            ("measured_stage2_s", Json::num(self.measured_stage2_s)),
            ("predicted_stage1_s", Json::num(self.predicted_stage1_s)),
            ("predicted_stage2_s", Json::num(self.predicted_stage2_s)),
        ])
    }
}

/// Which trigger caused a re-plan event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplanTrigger {
    /// Measured survivors broke the sketch 3σ bound (and the row floor).
    Cardinality,
    /// Run-measured stage factors flipped a remaining edge's
    /// cheapest-strategy ranking beyond [`REGRET_MARGIN`].
    Regret,
}

impl ReplanTrigger {
    pub fn name(self) -> &'static str {
        match self {
            ReplanTrigger::Cardinality => "cardinality",
            ReplanTrigger::Regret => "regret",
        }
    }
}

/// One re-plan decision, for the ledger.  For cardinality events
/// `relative_error`/`bound` are the survivor-estimate error against the
/// 3σ bound; for regret events they are the assigned strategy's relative
/// cost excess against [`REGRET_MARGIN`].
#[derive(Clone, Debug)]
pub struct ReplanEvent {
    pub trigger: ReplanTrigger,
    /// The edge whose measurement fired the trigger.
    pub after_edge: String,
    pub estimated_survivors: u64,
    pub measured_survivors: u64,
    pub relative_error: f64,
    pub bound: f64,
    /// `name strategy` labels of the tail before and after the re-plan.
    pub old_tail: Vec<String>,
    pub new_tail: Vec<String>,
}

impl ReplanEvent {
    pub fn to_json(&self) -> Json {
        let old: Vec<Json> = self.old_tail.iter().map(|s| Json::str(s.clone())).collect();
        let new: Vec<Json> = self.new_tail.iter().map(|s| Json::str(s.clone())).collect();
        Json::obj([
            ("trigger", Json::str(self.trigger.name())),
            ("after_edge", Json::str(self.after_edge.clone())),
            ("estimated_survivors", Json::num(self.estimated_survivors as f64)),
            ("measured_survivors", Json::num(self.measured_survivors as f64)),
            ("relative_error", Json::num(self.relative_error)),
            ("bound", Json::num(self.bound)),
            ("old_tail", Json::Arr(old)),
            ("new_tail", Json::Arr(new)),
        ])
    }
}

/// One mid-build filter re-size, for the ledger.
#[derive(Clone, Debug)]
pub struct ResizeEvent {
    /// The bloom edge whose filter was rebuilt before broadcast.
    pub edge: String,
    pub old_eps: f64,
    pub new_eps: f64,
    /// Build-side approximate count the corrected ε was solved on.
    pub build_estimate: u64,
    /// Measured probe stream length at the edge's start.
    pub probe_rows: u64,
}

impl ResizeEvent {
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("edge", Json::str(self.edge.clone())),
            ("old_eps", Json::num(self.old_eps)),
            ("new_eps", Json::num(self.new_eps)),
            ("build_estimate", Json::num(self.build_estimate as f64)),
            ("probe_rows", Json::num(self.probe_rows as f64)),
        ])
    }
}

/// Everything the adaptive loop recorded during one execution: one
/// observation per executed edge, one event per re-plan, one entry per
/// mid-build re-size.  Static runs still fill `observations` (they feed
/// the calibration store); their `events` and `resizes` are always
/// empty.
#[derive(Clone, Debug)]
pub struct ReplanLedger {
    pub policy: ReplanPolicy,
    pub bound: f64,
    /// Absolute row floor both triggers must clear.
    pub floor: u64,
    pub observations: Vec<EdgeObservation>,
    pub events: Vec<ReplanEvent>,
    pub resizes: Vec<ResizeEvent>,
}

impl ReplanLedger {
    pub fn new(policy: ReplanPolicy, floor: u64) -> ReplanLedger {
        ReplanLedger {
            policy,
            bound: trigger_bound(),
            floor,
            observations: Vec::new(),
            events: Vec::new(),
            resizes: Vec::new(),
        }
    }

    /// Events fired by a specific trigger.
    pub fn events_by(&self, trigger: ReplanTrigger) -> usize {
        self.events.iter().filter(|e| e.trigger == trigger).count()
    }

    pub fn to_json(&self) -> Json {
        let obs: Vec<Json> = self.observations.iter().map(|o| o.to_json()).collect();
        let events: Vec<Json> = self.events.iter().map(|e| e.to_json()).collect();
        let resizes: Vec<Json> = self.resizes.iter().map(|r| r.to_json()).collect();
        Json::obj([
            ("policy", Json::str(self.policy.name())),
            ("bound", Json::num(self.bound)),
            ("floor", Json::num(self.floor as f64)),
            ("observations", Json::Arr(obs)),
            ("events", Json::Arr(events)),
            ("resizes", Json::Arr(resizes)),
        ])
    }
}

/// `name strategy` labels of a plan tail (what [`ReplanEvent`] records).
pub fn tail_labels(edges: &[PlannedEdge]) -> Vec<String> {
    edges.iter().map(|e| format!("{} {}", e.name, e.strategy.label())).collect()
}

/// What [`regret_flip`] found: a remaining edge whose assigned strategy
/// is no longer competitive under the run-measured stage factors.
#[derive(Clone, Debug)]
pub struct RegretFinding {
    pub edge: String,
    pub assigned: String,
    pub cheapest: String,
    pub assigned_s: f64,
    pub cheapest_s: f64,
}

/// Re-price every remaining edge's strategies under the run-measured
/// §7 stage factors and report the first edge whose assigned strategy
/// costs more than the cheapest by over [`REGRET_MARGIN`] — the
/// strategy-regret trigger.  The whole [`super::StrategyKind`] table is
/// re-priced through [`predict_all`] at the re-solved ε*; the bloom
/// family's assigned cost is re-evaluated at its *assigned* ε on the
/// matching calibrated variant model (a materially mis-sized ε on a
/// still-bloom edge is regret too), while broadcast and sort-merge
/// predictions carry no §7 stage split, so the factors do not apply to
/// them.
pub fn regret_flip(
    cfg: &ClusterConfig,
    factors: (f64, f64),
    remaining: &[PlannedEdge],
) -> Option<RegretFinding> {
    for e in remaining {
        if !e.has_estimates() {
            continue;
        }
        let model = CostCalibration::scale(edge_cost_model(cfg, &e.stats), factors);
        let opt = newton::optimal_epsilon(&model);
        let prediction =
            predict_all(cfg, &e.stats, Some(factors), &model, opt.eps, opt.interior, opt.eps);
        let assigned_s = match &e.strategy {
            EdgeStrategy::Bloom { eps } => model.total(*eps),
            EdgeStrategy::BloomPartitioned { eps } => {
                CostCalibration::scale(partitioned_cost_model(cfg, &e.stats), factors).total(*eps)
            }
            EdgeStrategy::BloomExchange { eps } => {
                CostCalibration::scale(exchange_cost_model(cfg, &e.stats), factors).total(*eps)
            }
            other => prediction.cost_of(other.kind()),
        };
        let cheapest = prediction.cheapest();
        if assigned_s > cheapest.seconds * (1.0 + REGRET_MARGIN) {
            return Some(RegretFinding {
                edge: e.name.clone(),
                assigned: e.strategy.label(),
                cheapest: EdgeStrategy::for_kind(cheapest.kind, opt.eps).label(),
                assigned_s,
                cheapest_s: cheapest.seconds,
            });
        }
    }
    None
}

/// The mid-build re-size decision: given the measured workload of the
/// edge being executed (`stats` carries the measured probe stream and
/// the build phase's approximate count) and the ε the filter was just
/// built at, return the corrected ε when rebuilding before broadcast
/// still pays with the **whole §7 stage 1 charged a second time** —
/// conservative, since the rebuild actually skips the approximate count.
///
/// The decision is made on the **physical filters**, not the requested
/// ε's: sizing rounds bits up to a power of two
/// ([`BloomParams::optimal`]), so a loose requested ε often already
/// realises a much tighter rate — or even the exact filter the corrected
/// ε would build, in which case there is nothing to fix.  Stage 1 is
/// priced at the ε whose raw size formula yields the new physical bit
/// count (folding the rounding into the model's `ln(1/ε)` term), stage 2
/// at the realised rates the probe will actually see.
///
/// The payback test makes this a one-direction correction: a too-loose
/// filter is worth rebuilding tighter (the false-positive shuffle is
/// still ahead of us), while a too-tight filter's cost is sunk —
/// `new.m_bits ≤ old.m_bits` never pays.
pub fn resize_epsilon(
    cfg: &ClusterConfig,
    stats: &EdgeStats,
    old_eps: f64,
    factors: Option<(f64, f64)>,
) -> Option<f64> {
    let mut model = edge_cost_model(cfg, stats);
    if let Some(f) = factors {
        model = CostCalibration::scale(model, f);
    }
    let opt = newton::optimal_epsilon(&model);
    let ratio = (opt.eps / old_eps).max(old_eps / opt.eps);
    if !ratio.is_finite() || ratio < RESIZE_RATIO {
        return None;
    }
    let n = stats.build_distinct.max(1);
    let old = BloomParams::optimal(n, old_eps);
    let new = BloomParams::optimal(n, opt.eps);
    if new.m_bits <= old.m_bits {
        return None;
    }
    let ln2 = std::f64::consts::LN_2;
    let size_eps = (-(new.m_bits as f64) * ln2 / (1.44 * n as f64)).exp();
    let keep_s = model.join(old.realized_fpr(n));
    let resize_s = model.bloom(size_eps) + model.join(new.realized_fpr(n));
    if resize_s < keep_s {
        Some(opt.eps)
    } else {
        None
    }
}

/// Re-plan the not-yet-executed tail of a star plan against the
/// *measured* residual stream: re-rank the remaining dimensions, re-derive
/// each tail edge's workload from `measured_residual`, and re-price every
/// strategy (re-solving bloom ε* with Newton on the observed residual).
/// `factors` are the §7 stage-scale factors the re-pricing trusts — the
/// persistent calibration's under [`ReplanPolicy::Adaptive`], the
/// run-measured ones under [`ReplanPolicy::Regret`].
///
/// Returns `None` when the plan carries no sketch features for some
/// remaining relation (e.g. a strategy-forced test plan) — re-planning
/// needs the catalog's per-dimension estimates to re-derive workloads.
pub fn replan_remaining(
    cluster: &Cluster,
    spec: &PlanSpec,
    factors: Option<(f64, f64)>,
    dim_stats: &[DimStats],
    remaining: &[PlannedEdge],
    measured_residual: u64,
) -> Option<Vec<PlannedEdge>> {
    let mut dims = Vec::with_capacity(remaining.len());
    for e in remaining {
        dims.push(dim_stats.iter().find(|d| d.relation == e.relation)?.clone());
    }
    let residual = measured_residual.max(1) as f64;
    rank_dims(&mut dims, residual, spec.pushdown);
    let edge_list = derive_edge_stats(&dims, residual, spec.pushdown);
    Some(price_edges_with(cluster.config(), spec.eps_mode, factors, edge_list))
}

/// Re-plan a chain tail: the chain's propagated estimates (the tail
/// edge's build side is the head edge's output) are rescaled by the
/// measured contraction `ratio` (measured / expected survivors of the
/// edge that fired), then re-priced exactly like a fresh plan — strategy
/// and ε* re-decided per edge under `factors`.
pub fn replan_chain_tail(
    cfg: &ClusterConfig,
    eps_mode: EpsMode,
    factors: Option<(f64, f64)>,
    remaining: &[PlannedEdge],
    ratio: f64,
) -> Vec<PlannedEdge> {
    let list = remaining
        .iter()
        .map(|e| {
            let mut st = e.stats.clone();
            st.build_rows = ((st.build_rows as f64 * ratio).round() as u64).max(1);
            st.build_distinct = ((st.build_distinct as f64 * ratio).round() as u64).max(1);
            st.matched_rows =
                ((st.matched_rows as f64 * ratio).round() as u64).clamp(1, st.probe_rows);
            (e.name.clone(), e.relation, st)
        })
        .collect();
    price_edges_with(cfg, eps_mode, factors, list)
}

/// Re-plan a graph-sweep tail mid-sweep: the remaining edges keep their
/// order (a suffix of a tree-valid order is tree-valid — every parent
/// either already joined or sits earlier in the suffix), but each edge's
/// probe-side workload is rescaled by the measured contraction `ratio`
/// (measured / expected survivors of the edge that fired) before
/// strategy and ε* are re-decided under `factors`.  The per-edge
/// `matched / probe` ratio is preserved rather than clamped — graph
/// edges on non-unique keys legitimately fan the stream out — and the
/// build sides stay as the bottom-up sweep left them: phase A already
/// ran, so reduction costs are sunk and only the stream-join legs are
/// worth re-pricing.
pub fn replan_graph_tail(
    cfg: &ClusterConfig,
    eps_mode: EpsMode,
    factors: Option<(f64, f64)>,
    remaining: &[PlannedEdge],
    ratio: f64,
) -> Vec<PlannedEdge> {
    let list = remaining
        .iter()
        .map(|e| {
            let mut st = e.stats.clone();
            let sel = st.matched_rows as f64 / st.probe_rows.max(1) as f64;
            st.probe_rows = ((st.probe_rows as f64 * ratio).round() as u64).max(1);
            st.matched_rows = ((st.probe_rows as f64 * sel).round() as u64).max(1);
            (e.name.clone(), e.relation, st)
        })
        .collect();
    price_edges_with(cfg, eps_mode, factors, list)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;

    #[test]
    fn policy_parse_roundtrips() {
        for p in [ReplanPolicy::Static, ReplanPolicy::Adaptive, ReplanPolicy::Regret] {
            assert_eq!(ReplanPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(ReplanPolicy::parse("aggressive"), None);
        assert_eq!(ReplanPolicy::default(), ReplanPolicy::Static);
        assert!(!ReplanPolicy::Static.is_adaptive());
        assert!(ReplanPolicy::Adaptive.is_adaptive());
        assert!(ReplanPolicy::Regret.is_adaptive());
    }

    #[test]
    fn filter_pass_fraction_is_eps_inflated_selectivity() {
        let stats = EdgeStats { probe_rows: 1_000, matched_rows: 200, ..EdgeStats::default() };
        // ε = 0: exactly the join selectivity
        assert!((filter_pass_fraction(&stats, 0.0) - 0.2).abs() < 1e-12);
        // ε = 1: everything passes the filter
        assert!((filter_pass_fraction(&stats, 1.0) - 1.0).abs() < 1e-12);
        // in between: frac + ε·(1−frac)
        assert!((filter_pass_fraction(&stats, 0.05) - (0.2 + 0.05 * 0.8)).abs() < 1e-12);
        // monotone in ε and never below the true selectivity
        assert!(filter_pass_fraction(&stats, 0.1) > filter_pass_fraction(&stats, 0.01));
        assert!(filter_pass_fraction(&stats, 0.01) >= 0.2);
    }

    #[test]
    fn bound_matches_hll_three_sigma() {
        let b = trigger_bound();
        assert!((b - HyperLogLog::relative_error_bound()).abs() < 1e-15);
        assert!(b > 0.0 && b < 0.1, "P=12 3σ should be a few percent, got {b}");
    }

    #[test]
    fn trigger_fires_only_outside_the_bound() {
        let bound = trigger_bound();
        // exactly on the estimate: never
        assert!(!should_replan(10_000, 10_000, bound, 1));
        // inside the bound in both directions: never
        let delta = (10_000.0 * bound * 0.9) as u64;
        assert!(!should_replan(10_000, 10_000 + delta, bound, 1));
        assert!(!should_replan(10_000, 10_000 - delta, bound, 1));
        // outside the bound in both directions: always
        let delta = (10_000.0 * bound * 1.1).ceil() as u64;
        assert!(should_replan(10_000, 10_000 + delta, bound, 1));
        assert!(should_replan(10_000, 10_000 - delta, bound, 1));
    }

    #[test]
    fn floor_suppresses_small_absolute_residuals() {
        let bound = trigger_bound();
        // 10 estimated vs 30 measured: 200 % relative error, but only a
        // 20-row residual — the floor keeps the tail as planned
        assert!(should_replan(10, 30, bound, 1));
        assert!(!should_replan(10, 30, bound, DEFAULT_ROW_FLOOR));
        // the same relative error at scale clears the floor
        assert!(should_replan(10_000, 30_000, bound, DEFAULT_ROW_FLOOR));
        // exactly at the floor fires; one below does not
        assert!(should_replan(10, 10 + DEFAULT_ROW_FLOOR, bound, DEFAULT_ROW_FLOOR));
        assert!(!should_replan(10, 10 + DEFAULT_ROW_FLOOR - 1, bound, DEFAULT_ROW_FLOOR));
    }

    #[test]
    fn expected_survivors_rescales_to_the_measured_probe() {
        let stats = EdgeStats { probe_rows: 1000, matched_rows: 300, ..EdgeStats::default() };
        assert_eq!(expected_survivors(&stats, 100), 30);
        assert_eq!(expected_survivors(&stats, 1000), 300);
        assert_eq!(expected_survivors(&stats, 0), 0);
    }

    #[test]
    fn zero_estimate_does_not_divide_by_zero() {
        assert!(should_replan(0, 100, trigger_bound(), 1));
        assert!(!should_replan(0, 0, trigger_bound(), 1));
    }

    /// A pass-through edge (nothing filtrable) over a tiny dimension:
    /// broadcast is the true cheapest by a wide margin (see
    /// `costing::tests::tiny_dimension_prefers_broadcast`).
    fn broadcast_favored() -> EdgeStats {
        EdgeStats {
            build_rows: 2_000,
            build_distinct: 2_000,
            build_row_bytes: 16.0,
            probe_rows: 10_000_000,
            probe_row_bytes: 16.0,
            matched_rows: 9_500_000,
        }
    }

    #[test]
    fn regret_fires_on_a_mispriced_assignment_and_not_on_the_cheapest() {
        let cfg = ClusterConfig::default();
        let wrong = PlannedEdge {
            strategy: EdgeStrategy::Bloom { eps: 0.05 },
            stats: broadcast_favored(),
            ..PlannedEdge::forced(Relation::Part, "⋈part", EdgeStrategy::Broadcast)
        };
        let finding = regret_flip(&cfg, (1.0, 1.0), std::slice::from_ref(&wrong))
            .expect("bloom on a pass-through edge is regret");
        assert_eq!(finding.edge, "⋈part");
        assert!(finding.cheapest.contains("broadcast"), "{finding:?}");
        assert!(finding.assigned_s > finding.cheapest_s * (1.0 + REGRET_MARGIN));

        let right = PlannedEdge { strategy: EdgeStrategy::Broadcast, ..wrong.clone() };
        assert!(regret_flip(&cfg, (1.0, 1.0), std::slice::from_ref(&right)).is_none());
        // edges without estimates (forced test plans) are never judged
        let eps = EdgeStrategy::Bloom { eps: 0.05 };
        let forced = PlannedEdge::forced(Relation::Part, "⋈part", eps);
        assert!(regret_flip(&cfg, (1.0, 1.0), std::slice::from_ref(&forced)).is_none());
    }

    /// A heavily filtrable edge (see
    /// `costing::tests::filterable_fact_edge_prefers_bloom_over_sortmerge`).
    fn bloom_favored() -> EdgeStats {
        EdgeStats {
            build_rows: 5_000_000,
            build_distinct: 5_000_000,
            build_row_bytes: 16.0,
            probe_rows: 50_000_000,
            probe_row_bytes: 16.0,
            matched_rows: 2_000_000,
        }
    }

    #[test]
    fn resize_fires_only_on_a_loose_filter_that_pays() {
        let cfg = ClusterConfig::default();
        let stats = bloom_favored();
        let model = edge_cost_model(&cfg, &stats);
        let opt = newton::optimal_epsilon(&model).eps;
        // far too loose: the false-positive shuffle ahead dwarfs a rebuild
        let fixed = resize_epsilon(&cfg, &stats, 0.5, None).expect("loose filter must re-size");
        assert!((fixed - opt).abs() < 1e-9, "{fixed} vs {opt}");
        // already optimal: ratio below RESIZE_RATIO, never
        assert!(resize_epsilon(&cfg, &stats, opt, None).is_none());
        // too tight: the cost is sunk, re-sizing can never pay
        assert!(resize_epsilon(&cfg, &stats, opt / 100.0, None).is_none());
    }

    #[test]
    fn resize_respects_measured_stage_factors() {
        let cfg = ClusterConfig::default();
        let stats = bloom_favored();
        let plain = resize_epsilon(&cfg, &stats, 0.5, None).unwrap();
        // stage 2 measured 3x the constructed model: false positives are
        // dearer, so the corrected optimum is tighter than the plain one
        let tight = resize_epsilon(&cfg, &stats, 0.5, Some((1.0, 3.0))).unwrap();
        assert!(tight < plain, "{tight} vs {plain}");
    }

    #[test]
    fn chain_tail_rescales_and_reprices() {
        let cfg = ClusterConfig::default();
        let tail = PlannedEdge {
            strategy: EdgeStrategy::Bloom { eps: 0.05 },
            stats: EdgeStats {
                build_rows: 100_000,
                build_distinct: 90_000,
                build_row_bytes: 24.0,
                probe_rows: 6_000_000,
                probe_row_bytes: 56.0,
                matched_rows: 3_000_000,
            },
            ..PlannedEdge::forced(Relation::Orders, "lineitem⋈orders'", EdgeStrategy::Broadcast)
        };
        let new = replan_chain_tail(
            &cfg,
            EpsMode::PerFilter,
            None,
            std::slice::from_ref(&tail),
            0.1,
        );
        assert_eq!(new.len(), 1);
        assert_eq!(new[0].stats.build_rows, 10_000);
        assert_eq!(new[0].stats.build_distinct, 9_000);
        assert_eq!(new[0].stats.matched_rows, 300_000);
        // probe side is unchanged — the fact scan is what it is
        assert_eq!(new[0].stats.probe_rows, 6_000_000);
    }

    #[test]
    fn graph_tail_replan_rescales_probe_and_keeps_fanout() {
        let cfg = ClusterConfig::default();
        // a fan-out edge: nationkey-style, matched > probe
        let tail = vec![PlannedEdge {
            stats: EdgeStats {
                build_rows: 50,
                build_distinct: 25,
                build_row_bytes: 12.0,
                probe_rows: 10_000,
                probe_row_bytes: 56.0,
                matched_rows: 20_000,
            },
            ..PlannedEdge::forced(
                Relation::Supplier,
                "⋈supplier",
                EdgeStrategy::Bloom { eps: 0.05 },
            )
        }];
        let new = replan_graph_tail(&cfg, EpsMode::PerFilter, None, &tail, 0.5);
        assert_eq!(new.len(), 1);
        assert_eq!(new[0].stats.probe_rows, 5_000);
        // matched / probe preserved (still 2.0) — no clamp to probe
        assert_eq!(new[0].stats.matched_rows, 10_000);
        // build side untouched: the bottom-up sweep already ran
        assert_eq!(new[0].stats.build_rows, 50);
        // and the unclamped expectation tracks the fan-out
        assert_eq!(graph_expected_survivors(&tail[0].stats, 1_000), 2_000);
        assert_eq!(expected_survivors(&tail[0].stats, 1_000), 1_000, "the star helper clamps");
    }

    #[test]
    fn ledger_json_has_all_sections() {
        let mut l = ReplanLedger::new(ReplanPolicy::Adaptive, DEFAULT_ROW_FLOOR);
        l.events.push(ReplanEvent {
            trigger: ReplanTrigger::Cardinality,
            after_edge: "⋈orders".into(),
            estimated_survivors: 100,
            measured_survivors: 10,
            relative_error: 0.9,
            bound: l.bound,
            old_tail: vec!["⋈part bloom(eps=0.0100)".into()],
            new_tail: vec!["⋈part broadcast".into()],
        });
        l.resizes.push(ResizeEvent {
            edge: "⋈orders".into(),
            old_eps: 0.2,
            new_eps: 0.01,
            build_estimate: 5_000,
            probe_rows: 100_000,
        });
        let j = l.to_json();
        assert_eq!(j.get("policy").unwrap().as_str(), Some("adaptive"));
        assert_eq!(j.get("floor").unwrap().as_f64(), Some(DEFAULT_ROW_FLOOR as f64));
        assert_eq!(j.get("events").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(j.get("resizes").unwrap().as_arr().unwrap().len(), 1);
        assert!(j.get("observations").unwrap().as_arr().unwrap().is_empty());
        assert_eq!(l.events_by(ReplanTrigger::Cardinality), 1);
        assert_eq!(l.events_by(ReplanTrigger::Regret), 0);
        // the writer emits parseable JSON
        assert!(crate::util::Json::parse(&j.to_string()).is_ok());
    }
}
