//! Cache-line-blocked Bloom filter (Putze et al. style): all k bits of a
//! key land in one 512-bit block, so a probe touches exactly one cache
//! line.  Ablation A4 compares probe throughput and realised FPR against
//! the standard filter — the trade is ~0.1–0.5 extra bits/key of FPR for
//! locality, mirroring the paper's observation that probe cost is part of
//! the ε-linear term.

use super::batch::{live_mask, push_live, SelectionVector, PROBE_CHUNK};
use super::hash::{mix32, HashPair};
#[cfg(test)]
use super::hash::K_MAX;
use super::KeyFilter;

const BLOCK_BITS: u64 = 512; // one cache line
const BLOCK_WORDS: usize = (BLOCK_BITS / 32) as usize;

#[derive(Clone, Debug)]
pub struct BlockedBloomFilter {
    blocks: Vec<[u32; BLOCK_WORDS]>,
    k: u32,
    block_mask: u32,
}

impl BlockedBloomFilter {
    /// Same global bit budget as the standard filter for fair ablations.
    pub fn with_optimal(n: u64, fpr: f64) -> Self {
        let p = super::BloomParams::optimal(n, fpr);
        let n_blocks = (p.m_bits / BLOCK_BITS).max(1).next_power_of_two();
        BlockedBloomFilter {
            blocks: vec![[0u32; BLOCK_WORDS]; n_blocks as usize],
            k: p.k,
            block_mask: (n_blocks - 1) as u32,
        }
    }

    #[inline]
    fn slots(&self, key: u64) -> (usize, HashPair) {
        let hp = HashPair::of_key(key);
        // block chosen by an independent mix so in-block bits stay unbiased
        let block = (mix32(hp.h1 ^ 0x6A09_E667) & self.block_mask) as usize;
        (block, hp)
    }

    #[inline]
    pub fn insert(&mut self, key: u64) {
        let (block, hp) = self.slots(key);
        let b = &mut self.blocks[block];
        for j in 0..self.k {
            let p = hp.position(j, (BLOCK_BITS - 1) as u32);
            b[(p >> 5) as usize] |= 1 << (p & 31);
        }
    }

    #[inline]
    pub fn contains_key(&self, key: u64) -> bool {
        let (block, hp) = self.slots(key);
        let b = &self.blocks[block];
        for j in 0..self.k {
            let p = hp.position(j, (BLOCK_BITS - 1) as u32);
            if b[(p >> 5) as usize] & (1 << (p & 31)) == 0 {
                return false;
            }
        }
        true
    }
}

impl KeyFilter for BlockedBloomFilter {
    fn contains(&self, key: u64) -> bool {
        self.contains_key(key)
    }

    fn size_bits(&self) -> u64 {
        self.blocks.len() as u64 * BLOCK_BITS
    }

    /// Chunked probe: resolve every key's (block, hash pair) up front,
    /// then run the k in-block bit tests position-major over the chunk
    /// under one survivor bitmask (each lane still touches exactly one
    /// cache line — the blocked filter's whole point).
    fn probe_batch(&self, keys: &[u64], sel: &mut SelectionVector) {
        sel.clear();
        let mut slots = [(0usize, HashPair { h1: 0, h2: 1 }); PROBE_CHUNK];
        for (chunk_no, chunk) in keys.chunks(PROBE_CHUNK).enumerate() {
            for (slot, &key) in slots.iter_mut().zip(chunk) {
                *slot = self.slots(key);
            }
            let mut live = live_mask(chunk.len());
            for j in 0..self.k {
                if live == 0 {
                    break;
                }
                let mut m = live;
                while m != 0 {
                    let i = m.trailing_zeros() as usize;
                    m &= m - 1;
                    let (block, hp) = slots[i];
                    let p = hp.position(j, (BLOCK_BITS - 1) as u32);
                    if self.blocks[block][(p >> 5) as usize] & (1 << (p & 31)) == 0 {
                        live &= !(1u64 << i);
                    }
                }
            }
            push_live(sel, chunk_no, live);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn never_false_negative() {
        let mut f = BlockedBloomFilter::with_optimal(5_000, 0.02);
        let mut rng = Rng::new(11);
        let keys: Vec<u64> = (0..5_000).map(|_| rng.next_u64()).collect();
        for &k in &keys {
            f.insert(k);
        }
        assert!(keys.iter().all(|&k| f.contains_key(k)));
    }

    #[test]
    fn fpr_degrades_gracefully_vs_standard() {
        let n = 20_000u64;
        let eps = 0.01;
        let mut blocked = BlockedBloomFilter::with_optimal(n, eps);
        let mut rng = Rng::new(12);
        for _ in 0..n {
            blocked.insert(rng.next_u64());
        }
        let trials = 50_000;
        let fp = (0..trials).filter(|_| blocked.contains_key(rng.next_u64())).count();
        let measured = fp as f64 / trials as f64;
        // blocked filters pay a locality tax; stay within ~8x of target
        assert!(measured < eps * 8.0, "blocked fpr {measured}");
    }

    #[test]
    fn probe_batch_matches_scalar() {
        let mut f = BlockedBloomFilter::with_optimal(3_000, 0.05);
        let mut rng = Rng::new(13);
        for _ in 0..3_000 {
            f.insert(rng.next_u64());
        }
        let keys: Vec<u64> = (0..801).map(|_| rng.next_u64()).collect();
        let mut sel = SelectionVector::new();
        f.probe_batch(&keys, &mut sel);
        let want: Vec<u32> = keys
            .iter()
            .enumerate()
            .filter(|(_, &k)| f.contains_key(k))
            .map(|(i, _)| i as u32)
            .collect();
        assert_eq!(sel.indices(), want.as_slice());
    }

    #[test]
    fn k_max_respected() {
        let f = BlockedBloomFilter::with_optimal(10, 1e-9);
        assert!(f.k as usize <= K_MAX);
    }
}
