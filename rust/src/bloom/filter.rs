//! The paper's Bloom filter: optimal sizing, distributed partial build,
//! OR-merge, and a fast native probe (the XLA-kernel probe path lives in
//! `runtime::probe`; both share `bloom::hash`).

use super::batch::{live_mask, push_live, HashedChunk, SelectionVector, PROBE_CHUNK};
use super::hash::{HashPair, K_MAX};
use super::KeyFilter;

/// Sizing decision for an optimal filter (paper §5.2 step 2 / §7.1.1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BloomParams {
    /// Filter size in bits; always a power of two here (the `mod` is a
    /// bit-mask both natively and on the TPU VPU — DESIGN.md §6).
    pub m_bits: u64,
    /// Number of hash functions, `1..=K_MAX`.
    pub k: u32,
    /// The ε the caller asked for.
    pub requested_fpr: f64,
    /// Expected n the sizing was computed for.
    pub expected_items: u64,
}

impl BloomParams {
    /// Paper §7.1.1: `m ≈ n · 1.44 · log2(1/ε)`, rounded **up** to a power
    /// of two (ladder rung), `k = round(ln 2 · m/n)` clamped to `1..=K_MAX`.
    pub fn optimal(n: u64, fpr: f64) -> BloomParams {
        let n = n.max(1);
        let fpr = fpr.clamp(1e-9, 0.999);
        let bits = (n as f64) * 1.44 * (1.0 / fpr).log2();
        let m_bits = (bits.max(64.0).ceil() as u64).next_power_of_two();
        let k = ((m_bits as f64 / n as f64) * std::f64::consts::LN_2).round() as i64;
        let k = k.clamp(1, K_MAX as i64) as u32;
        BloomParams { m_bits, k, requested_fpr: fpr, expected_items: n }
    }

    /// Sizing for one shard of a key-range-partitioned filter: `n` total
    /// expected keys hash-split across `n_shards` equal slices, each
    /// slice sized independently at the same ε.  Hash routing
    /// (`cluster::shuffle::partition_of`) balances the slices, so the
    /// per-shard load is `n / n_shards`; the per-key bit budget — and
    /// hence the realized FPR — matches the monolithic filter's, while
    /// each shard can be built and placed at its owner node.
    pub fn sharded(n: u64, n_shards: usize, fpr: f64) -> BloomParams {
        Self::optimal((n / n_shards.max(1) as u64).max(1), fpr)
    }

    /// Explicit filter size (e.g. snapped to an artifact ladder rung),
    /// with the k that is optimal for that (m, n).
    pub fn with_m(n: u64, fpr: f64, m_bits: u64) -> BloomParams {
        assert!(m_bits.is_power_of_two() && m_bits >= 64);
        let n = n.max(1);
        let k = ((m_bits as f64 / n as f64) * std::f64::consts::LN_2).round() as i64;
        BloomParams {
            m_bits,
            k: k.clamp(1, K_MAX as i64) as u32,
            requested_fpr: fpr,
            expected_items: n,
        }
    }

    /// Theoretical FPR realised by (m, k) at load n:
    /// `(1 − e^{−kn/m})^k`.
    pub fn realized_fpr(&self, n: u64) -> f64 {
        let kn_m = self.k as f64 * n as f64 / self.m_bits as f64;
        (1.0 - (-kn_m).exp()).powi(self.k as i32)
    }

    pub fn size_bytes(&self) -> u64 {
        self.m_bits / 8
    }

    pub fn n_words(&self) -> usize {
        (self.m_bits / 32) as usize
    }
}

/// Partitioned-buildable Bloom filter over u32 words (same layout as the
/// kernel artifacts: bit `p` lives at word `p >> 5`, bit `p & 31`).
#[derive(Clone, Debug, PartialEq)]
pub struct BloomFilter {
    params: BloomParams,
    words: Vec<u32>,
    mask: u32,
}

impl BloomFilter {
    pub fn new(params: BloomParams) -> Self {
        assert!(params.m_bits.is_power_of_two() && params.m_bits >= 64);
        assert!((1..=K_MAX as u32).contains(&params.k));
        BloomFilter {
            words: vec![0; params.n_words()],
            mask: (params.m_bits - 1) as u32,
            params,
        }
    }

    pub fn with_optimal(n: u64, fpr: f64) -> Self {
        Self::new(BloomParams::optimal(n, fpr))
    }

    pub fn params(&self) -> BloomParams {
        self.params
    }

    pub fn words(&self) -> &[u32] {
        &self.words
    }

    /// Adopt externally-built words (e.g. from the XLA build artifact).
    pub fn from_words(params: BloomParams, words: Vec<u32>) -> Self {
        assert_eq!(words.len(), params.n_words());
        BloomFilter { words, mask: (params.m_bits - 1) as u32, params }
    }

    #[inline]
    pub fn insert(&mut self, key: u64) {
        let hp = HashPair::of_key(key);
        for j in 0..self.params.k {
            let p = hp.position(j, self.mask);
            self.words[(p >> 5) as usize] |= 1 << (p & 31);
        }
    }

    #[inline]
    pub fn contains_key(&self, key: u64) -> bool {
        let hp = HashPair::of_key(key);
        for j in 0..self.params.k {
            let p = hp.position(j, self.mask);
            if self.words[(p >> 5) as usize] & (1 << (p & 31)) == 0 {
                return false;
            }
        }
        true
    }

    /// Test a memoized chunk against this filter: the `k` bit tests run
    /// position-major over the chunk's cached [`HashPair`]s, clearing
    /// lanes from `live` — no key is re-hashed.  Returns the surviving
    /// mask (always a subset of `live`).  This is the per-filter half of
    /// the fused probe pipeline: one [`HashedChunk`] fill serves every
    /// filter in a fused group, and `probe_batch` itself is this method
    /// looped over chunks.
    ///
    /// [`HashedChunk`]: super::batch::HashedChunk
    #[inline]
    pub fn test_hashed(&self, chunk: &HashedChunk, mut live: u64) -> u64 {
        for j in 0..self.params.k {
            if live == 0 {
                break;
            }
            let mut m = live;
            while m != 0 {
                let i = m.trailing_zeros() as usize;
                m &= m - 1;
                let p = chunk.pair(i).position(j, self.mask);
                if self.words[(p >> 5) as usize] & (1 << (p & 31)) == 0 {
                    live &= !(1u64 << i);
                }
            }
        }
        live
    }

    /// OR-merge a partial filter built with identical params (paper §5.1
    /// change #1: per-partition partials merged on the way to the driver).
    pub fn merge(&mut self, other: &BloomFilter) -> Result<(), MergeError> {
        if self.params != other.params {
            return Err(MergeError {
                ours: self.params,
                theirs: other.params,
            });
        }
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= *b;
        }
        Ok(())
    }

    /// Fraction of set bits (diagnostic: ~0.5 at design load for optimal k).
    pub fn fill_ratio(&self) -> f64 {
        let set: u64 = self.words.iter().map(|w| w.count_ones() as u64).sum();
        set as f64 / self.params.m_bits as f64
    }

    /// Serialize as length-prefixed little-endian words (what the
    /// simulated broadcast ships between nodes).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + self.words.len() * 4);
        out.extend_from_slice(&self.params.m_bits.to_le_bytes());
        out.extend_from_slice(&self.params.k.to_le_bytes());
        out.extend_from_slice(&(self.params.expected_items).to_le_bytes());
        for w in &self.words {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out
    }

    pub fn from_bytes(b: &[u8]) -> Result<Self, DecodeError> {
        if b.len() < 20 {
            return Err(DecodeError::Truncated);
        }
        let m_bits = u64::from_le_bytes(b[0..8].try_into().unwrap());
        let k = u32::from_le_bytes(b[8..12].try_into().unwrap());
        let n = u64::from_le_bytes(b[12..20].try_into().unwrap());
        if !m_bits.is_power_of_two() || !(1..=K_MAX as u64).contains(&(k as u64)) {
            return Err(DecodeError::BadHeader);
        }
        let n_words = (m_bits / 32) as usize;
        if b.len() != 20 + n_words * 4 {
            return Err(DecodeError::Truncated);
        }
        let words = b[20..]
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let params = BloomParams {
            m_bits,
            k,
            requested_fpr: f64::NAN, // not shipped; callers use realized_fpr
            expected_items: n,
        };
        Ok(BloomFilter { words, mask: (m_bits - 1) as u32, params })
    }
}

impl KeyFilter for BloomFilter {
    fn contains(&self, key: u64) -> bool {
        self.contains_key(key)
    }

    fn size_bits(&self) -> u64 {
        self.params.m_bits
    }

    /// Chunked probe: hash [`PROBE_CHUNK`] keys once into a
    /// [`HashedChunk`], then run the `k` bit tests position-major over
    /// the cached pairs with one survivor bitmask ([`Self::test_hashed`])
    /// — the mask early-exits dead lanes and whole dead chunks, and the
    /// selection is filled without any per-key allocation.  Single-filter
    /// probes and fused multi-filter groups share this exact code path.
    ///
    /// [`HashedChunk`]: super::batch::HashedChunk
    fn probe_batch(&self, keys: &[u64], sel: &mut SelectionVector) {
        sel.clear();
        let mut hashed = HashedChunk::new();
        for (chunk_no, chunk) in keys.chunks(PROBE_CHUNK).enumerate() {
            hashed.fill(chunk);
            let live = self.test_hashed(&hashed, live_mask(chunk.len()));
            push_live(sel, chunk_no, live);
        }
    }
}

#[derive(Debug)]
pub struct MergeError {
    pub ours: BloomParams,
    pub theirs: BloomParams,
}

impl std::fmt::Display for MergeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "cannot merge bloom filters with different params: {:?} vs {:?}",
            self.ours, self.theirs
        )
    }
}

impl std::error::Error for MergeError {}

#[derive(Debug)]
pub enum DecodeError {
    Truncated,
    BadHeader,
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::Truncated => write!(f, "bloom filter bytes truncated"),
            DecodeError::BadHeader => write!(f, "bloom filter header invalid"),
        }
    }
}

impl std::error::Error for DecodeError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn sizing_formula_matches_paper() {
        // n=1e6, eps=0.01 -> 1.44e6 * log2(100) = 9.57e6 bits -> 2^24
        let p = BloomParams::optimal(1_000_000, 0.01);
        assert_eq!(p.m_bits, 1 << 24);
        // k = ln2 * m/n = 0.693 * 16.78 = 11.6 -> 12
        assert_eq!(p.k, 12);
    }

    #[test]
    fn sizing_monotone_in_eps() {
        let mut last = u64::MAX;
        for eps in [0.5, 0.1, 0.01, 0.001, 1e-4] {
            let p = BloomParams::optimal(100_000, eps);
            assert!(p.m_bits <= last || p.m_bits >= last, "pow2 rounding");
            let raw = 100_000.0 * 1.44 * (1.0 / eps).log2();
            assert!(p.m_bits as f64 >= raw, "rounding must only add bits");
            last = p.m_bits;
        }
    }

    #[test]
    fn sharded_sizing_splits_the_budget() {
        let whole = BloomParams::optimal(1_000_000, 0.01);
        let shard = BloomParams::sharded(1_000_000, 8, 0.01);
        // each shard carries 1/8 of the keys with the same per-key bit
        // budget (modulo pow-2 rounding), so its FPR at design load
        // matches the monolithic filter's
        assert!(shard.m_bits <= whole.m_bits / 4, "{} vs {}", shard.m_bits, whole.m_bits);
        let whole_fpr = whole.realized_fpr(1_000_000);
        let shard_fpr = shard.realized_fpr(125_000);
        assert!((shard_fpr - whole_fpr).abs() < 0.01, "{shard_fpr} vs {whole_fpr}");
        // degenerate shard counts clamp instead of dividing by zero
        assert_eq!(BloomParams::sharded(100, 0, 0.05).expected_items, 100);
        assert_eq!(BloomParams::sharded(4, 8, 0.05).expected_items, 1);
    }

    #[test]
    fn never_false_negative() {
        let mut f = BloomFilter::with_optimal(10_000, 0.01);
        let mut rng = Rng::new(1);
        let keys: Vec<u64> = (0..10_000).map(|_| rng.next_u64()).collect();
        for &k in &keys {
            f.insert(k);
        }
        for &k in &keys {
            assert!(f.contains_key(k));
        }
    }

    #[test]
    fn fpr_tracks_request() {
        let n = 20_000u64;
        for eps in [0.2, 0.05, 0.01] {
            let mut f = BloomFilter::with_optimal(n, eps);
            let mut rng = Rng::new(2);
            for _ in 0..n {
                f.insert(rng.next_u64());
            }
            let trials = 100_000;
            let fp = (0..trials).filter(|_| f.contains_key(rng.next_u64())).count();
            let measured = fp as f64 / trials as f64;
            // pow-2 rounding only lowers FPR; allow sampling noise upward
            assert!(
                measured <= eps * 1.35 + 2e-3,
                "eps={eps} measured={measured}"
            );
        }
    }

    #[test]
    fn merge_equals_bulk_build() {
        let params = BloomParams::optimal(2_000, 0.03);
        let mut bulk = BloomFilter::new(params);
        let mut pa = BloomFilter::new(params);
        let mut pb = BloomFilter::new(params);
        let mut rng = Rng::new(3);
        for i in 0..2_000u64 {
            let key = rng.next_u64();
            bulk.insert(key);
            if i % 2 == 0 {
                pa.insert(key);
            } else {
                pb.insert(key);
            }
        }
        pa.merge(&pb).unwrap();
        assert_eq!(pa.words(), bulk.words());
    }

    #[test]
    fn merge_rejects_mismatched_params() {
        let mut a = BloomFilter::with_optimal(1000, 0.01);
        let b = BloomFilter::with_optimal(1000, 0.2);
        assert!(a.merge(&b).is_err());
    }

    #[test]
    fn serde_roundtrip() {
        let mut f = BloomFilter::with_optimal(500, 0.05);
        for k in 0..500u64 {
            f.insert(k * 7919);
        }
        let restored = BloomFilter::from_bytes(&f.to_bytes()).unwrap();
        assert_eq!(restored.words(), f.words());
        assert_eq!(restored.params().m_bits, f.params().m_bits);
        assert_eq!(restored.params().k, f.params().k);
        for k in 0..500u64 {
            assert!(restored.contains_key(k * 7919));
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(BloomFilter::from_bytes(&[1, 2, 3]).is_err());
        let mut good = BloomFilter::with_optimal(100, 0.1).to_bytes();
        good.truncate(good.len() - 1);
        assert!(BloomFilter::from_bytes(&good).is_err());
    }

    #[test]
    fn fill_ratio_near_half_at_design_load() {
        let n = 50_000u64;
        let mut f = BloomFilter::with_optimal(n, 0.01);
        let mut rng = Rng::new(4);
        for _ in 0..n {
            f.insert(rng.next_u64());
        }
        let r = f.fill_ratio();
        // pow-2 rounding over-allocates, so fill <= 0.5; must be substantial
        assert!(r > 0.15 && r <= 0.55, "fill {r}");
    }

    #[test]
    fn probe_batch_matches_scalar_including_partial_chunk() {
        let mut f = BloomFilter::with_optimal(5_000, 0.02);
        let mut rng = Rng::new(9);
        for _ in 0..5_000 {
            f.insert(rng.next_u64());
        }
        // 1_037 is deliberately not a multiple of PROBE_CHUNK
        let keys: Vec<u64> = (0..1_037).map(|_| rng.next_u64()).collect();
        let mut sel = SelectionVector::new();
        f.probe_batch(&keys, &mut sel);
        let want: Vec<u32> = keys
            .iter()
            .enumerate()
            .filter(|(_, &k)| f.contains_key(k))
            .map(|(i, _)| i as u32)
            .collect();
        assert_eq!(sel.indices(), want.as_slice());
    }

    #[test]
    fn realized_fpr_matches_theory_shape() {
        let p = BloomParams::optimal(10_000, 0.01);
        assert!(p.realized_fpr(10_000) <= 0.011);
        assert!(p.realized_fpr(100_000) > p.realized_fpr(10_000));
    }

    #[test]
    fn test_hashed_matches_scalar_and_respects_live_mask() {
        use crate::bloom::batch::HashedChunk;
        let mut f = BloomFilter::with_optimal(2_000, 0.03);
        let mut rng = Rng::new(17);
        for _ in 0..2_000 {
            f.insert(rng.below(50_000));
        }
        let keys: Vec<u64> = (0..64).map(|_| rng.below(50_000)).collect();
        let mut c = HashedChunk::new();
        c.fill(&keys);
        let live = f.test_hashed(&c, u64::MAX);
        for (i, &k) in keys.iter().enumerate() {
            assert_eq!(live & (1 << i) != 0, f.contains_key(k), "lane {i}");
        }
        // a pre-masked lane stays dead even when the key is a member
        let member = keys.iter().position(|&k| f.contains_key(k)).unwrap_or(0) as u64;
        let masked = !(1u64 << member);
        assert_eq!(f.test_hashed(&c, masked) & (1 << member), 0);
        assert_eq!(f.test_hashed(&c, masked), live & masked);
        // fill_live-refreshed lanes test identically to a full fill
        let mut partial = HashedChunk::new();
        partial.fill_live(&keys, live);
        assert_eq!(f.test_hashed(&partial, live), live);
    }
}
