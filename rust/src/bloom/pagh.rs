//! Compact approximate-membership structure after Pagh, Pagh & Rao 2005
//! ("An optimal Bloom filter replacement") — the optimisation the paper
//! cites but does not explore (§7.1.1: "they propose a structure where the
//! factor before the log is one").
//!
//! We implement the practical core of the idea: quotienting.  Each key is
//! hashed to `q + r` bits; the high `q` bits select a bucket, and only the
//! `r`-bit remainder is stored, in a sorted bucket.  Space is
//! `n·(log2(1/ε) + O(1))` bits — factor ~1 before the log instead of the
//! Bloom filter's 1.44 — at the cost of a slightly more expensive probe
//! (bucket binary search instead of k bit tests).  Like a Bloom filter it
//! has one-sided error: false positives only.

use super::batch::{SelectionVector, PROBE_CHUNK};
use super::hash::wide64;
use super::KeyFilter;

#[derive(Clone, Debug)]
pub struct PaghFilter {
    /// Bucket boundaries (CSR offsets), len = n_buckets + 1.
    offsets: Vec<u32>,
    /// Sorted r-bit remainders per bucket, stored in u16 (r <= 16).
    remainders: Vec<u16>,
    q_bits: u32,
    r_bits: u32,
}

impl PaghFilter {
    /// Build from the complete key set (static structure: the paper's
    /// small-table key set is known at filter-build time).
    pub fn build(keys: &[u64], fpr: f64) -> Self {
        let n = keys.len().max(1) as u64;
        // buckets ~ n/8 (expected bucket size 8) so the 32-bit CSR offset
        // array costs only ~4 bits/key; remainder bits then set ε:
        // P[false positive] ~ E[bucket size] * 2^-r = 8·2^-r, so spend
        // log2(1/ε) + 3 remainder bits.  Net ≈ log2(1/ε) + 7 bits/key —
        // the "factor one before the log" the PPR paper promises, vs the
        // Bloom filter's 1.44·log2(1/ε).
        let buckets = (n / 8).max(1).next_power_of_two();
        let q_bits = buckets.trailing_zeros().max(1);
        let r_bits =
            (((1.0 / fpr.clamp(1e-6, 0.5)).log2().ceil() as u32) + 3).clamp(4, 16);
        let n_buckets = 1usize << q_bits;

        let mut slots: Vec<(u32, u16)> = keys
            .iter()
            .map(|&k| {
                let h = wide64(k);
                let bucket = (h >> (64 - q_bits)) as u32;
                let rem = (h >> (64 - q_bits - r_bits as u32)) as u16 & r_mask(r_bits);
                (bucket, rem)
            })
            .collect();
        slots.sort_unstable();
        slots.dedup();

        let mut offsets = vec![0u32; n_buckets + 1];
        for &(b, _) in &slots {
            offsets[b as usize + 1] += 1;
        }
        for i in 0..n_buckets {
            offsets[i + 1] += offsets[i];
        }
        let remainders = slots.into_iter().map(|(_, r)| r).collect();
        PaghFilter { offsets, remainders, q_bits, r_bits }
    }

    pub fn contains_key(&self, key: u64) -> bool {
        self.lookup(wide64(key))
    }

    /// Bucket + remainder lookup for an already-computed [`wide64`] hash
    /// (shared by the scalar and the batched probe paths).
    #[inline]
    fn lookup(&self, h: u64) -> bool {
        let bucket = (h >> (64 - self.q_bits)) as usize;
        let rem = (h >> (64 - self.q_bits - self.r_bits)) as u16 & r_mask(self.r_bits);
        let lo = self.offsets[bucket] as usize;
        let hi = self.offsets[bucket + 1] as usize;
        self.remainders[lo..hi].binary_search(&rem).is_ok()
    }

    /// Actual storage cost (remainder array + offsets), for A4 space rows.
    pub fn storage_bits(&self) -> u64 {
        (self.remainders.len() as u64) * self.r_bits as u64
            + (self.offsets.len() as u64) * 32
    }

    pub fn r_bits(&self) -> u32 {
        self.r_bits
    }
}

#[inline]
fn r_mask(r_bits: u32) -> u16 {
    if r_bits >= 16 {
        u16::MAX
    } else {
        (1u16 << r_bits) - 1
    }
}

impl KeyFilter for PaghFilter {
    fn contains(&self, key: u64) -> bool {
        self.contains_key(key)
    }

    fn size_bits(&self) -> u64 {
        self.storage_bits()
    }

    /// Chunked probe: [`wide64`]-hash a whole chunk up front, then run
    /// the bucket lookups over the hashed chunk — the hash loop and the
    /// (cache-missing) bucket walk stop fighting over the same registers.
    fn probe_batch(&self, keys: &[u64], sel: &mut SelectionVector) {
        sel.clear();
        let mut hashes = [0u64; PROBE_CHUNK];
        for (chunk_no, chunk) in keys.chunks(PROBE_CHUNK).enumerate() {
            for (slot, &key) in hashes.iter_mut().zip(chunk) {
                *slot = wide64(key);
            }
            let base = (chunk_no * PROBE_CHUNK) as u32;
            for (i, &h) in hashes[..chunk.len()].iter().enumerate() {
                if self.lookup(h) {
                    sel.push(base + i as u32);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn never_false_negative() {
        let mut rng = Rng::new(21);
        let keys: Vec<u64> = (0..8_000).map(|_| rng.next_u64()).collect();
        let f = PaghFilter::build(&keys, 0.01);
        assert!(keys.iter().all(|&k| f.contains_key(k)));
    }

    #[test]
    fn fpr_near_target() {
        let mut rng = Rng::new(22);
        let keys: Vec<u64> = (0..20_000).map(|_| rng.next_u64()).collect();
        for eps in [0.1, 0.01] {
            let f = PaghFilter::build(&keys, eps);
            let trials = 50_000;
            let fp = (0..trials).filter(|_| f.contains_key(rng.next_u64())).count();
            let measured = fp as f64 / trials as f64;
            assert!(measured < eps * 3.0 + 1e-3, "eps {eps} measured {measured}");
        }
    }

    #[test]
    fn space_factor_beats_bloom_at_low_eps() {
        let mut rng = Rng::new(23);
        let keys: Vec<u64> = (0..50_000).map(|_| rng.next_u64()).collect();
        let eps = 0.001;
        let pagh = PaghFilter::build(&keys, eps);
        let bloom = super::super::BloomParams::optimal(keys.len() as u64, eps);
        let pagh_bits_per_key = pagh.storage_bits() as f64 / keys.len() as f64;
        let bloom_bits_per_key = bloom.m_bits as f64 / keys.len() as f64;
        assert!(
            pagh_bits_per_key < bloom_bits_per_key,
            "pagh {pagh_bits_per_key} vs bloom {bloom_bits_per_key}"
        );
    }

    #[test]
    fn probe_batch_matches_scalar() {
        let mut rng = Rng::new(24);
        let keys: Vec<u64> = (0..6_000).map(|_| rng.next_u64()).collect();
        let f = PaghFilter::build(&keys, 0.01);
        let probe: Vec<u64> =
            keys.iter().copied().take(300).chain((0..700).map(|_| rng.next_u64())).collect();
        let mut sel = SelectionVector::new();
        f.probe_batch(&probe, &mut sel);
        let want: Vec<u32> = probe
            .iter()
            .enumerate()
            .filter(|(_, &k)| f.contains_key(k))
            .map(|(i, _)| i as u32)
            .collect();
        assert_eq!(sel.indices(), want.as_slice());
    }

    #[test]
    fn handles_duplicates_and_empty() {
        let f = PaghFilter::build(&[], 0.01);
        assert!(!f.contains_key(42));
        let f = PaghFilter::build(&[7, 7, 7], 0.01);
        assert!(f.contains_key(7));
    }
}
