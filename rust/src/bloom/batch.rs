//! Batched membership probing: the selection-vector half of the
//! vectorized probe pipeline.
//!
//! The scalar probe path (`contains_key` in a per-row loop) recomputes
//! the hash pair, branches, and bumps an output vector one key at a
//! time.  The batched path hashes a whole chunk of [`PROBE_CHUNK`] keys
//! up front, keeps the chunk's survivors in one `u64` bitmask while the
//! `k` bit tests run position-major over the chunk, and only then spills
//! the surviving **row indices** into a reusable [`SelectionVector`] —
//! no per-key allocation, no cloned rows.  Downstream operators gather
//! through the selection instead of materialising survivor rows, which
//! is what makes the plan executor's hot path allocation-light.
//!
//! Every [`super::KeyFilter`] gets a default scalar `probe_batch`; the
//! three concrete filters override it with the chunked implementation
//! (see `filter.rs`, `blocked.rs`, `pagh.rs`).  The equivalence property
//! — `probe_batch` selects exactly the keys `contains` accepts — is
//! pinned by `rust/tests/probe_batch_equivalence.rs`.

use super::hash::HashPair;

/// Keys hashed per chunk: one `u64` survivor mask covers the chunk, so
/// the inner bit-test loop is branch-light and the mask early-exits as
/// soon as a chunk has no survivors left.
pub const PROBE_CHUNK: usize = 64;

/// A chunk's worth of memoized hash pairs — the shared `wide64` hash
/// cache of the fused probe pipeline.
///
/// A chunk of up to [`PROBE_CHUNK`] keys is hashed **once**; every
/// filter that tests the chunk afterwards ([`super::BloomFilter::
/// test_hashed`]) reuses the stored [`HashPair`]s and only clears bits
/// from a live mask.  The single-filter `probe_batch` path goes through
/// the same cache ([`HashedChunk::fill`] + `test_hashed`), and a fused
/// group refreshes only the still-live lanes per edge
/// ([`HashedChunk::fill_live`]) — dead lanes are never re-hashed.
///
/// The memoized word for a lane is exactly [`super::hash::wide64`]
/// (`(h1 << 32) | h2`), pinned by the same golden vectors as the scalar
/// path, so a cache bug cannot silently diverge from `contains_key`.
#[derive(Clone, Debug)]
pub struct HashedChunk {
    pairs: [HashPair; PROBE_CHUNK],
    len: usize,
}

impl Default for HashedChunk {
    fn default() -> Self {
        Self::new()
    }
}

impl HashedChunk {
    pub fn new() -> Self {
        HashedChunk { pairs: [HashPair { h1: 0, h2: 1 }; PROBE_CHUNK], len: 0 }
    }

    /// Hash every lane of `keys` (≤ [`PROBE_CHUNK`]) into the cache.
    #[inline]
    pub fn fill(&mut self, keys: &[u64]) {
        debug_assert!(keys.len() <= PROBE_CHUNK);
        self.len = keys.len();
        for (slot, &key) in self.pairs.iter_mut().zip(keys) {
            *slot = HashPair::of_key(key);
        }
    }

    /// Hash only the lanes of `keys` still set in `live` — what a fused
    /// group's non-leading edge does: lanes an earlier filter already
    /// rejected are never hashed for this edge's key column.
    #[inline]
    pub fn fill_live(&mut self, keys: &[u64], live: u64) {
        debug_assert!(keys.len() <= PROBE_CHUNK);
        self.len = keys.len();
        let mut m = live & live_mask(keys.len());
        while m != 0 {
            let i = m.trailing_zeros() as usize;
            m &= m - 1;
            self.pairs[i] = HashPair::of_key(keys[i]);
        }
    }

    /// Lanes currently cached.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The memoized double-hash pair of lane `i`.
    #[inline(always)]
    pub fn pair(&self, i: usize) -> HashPair {
        self.pairs[i]
    }

    /// The packed 64-bit hash word of lane `i` — identical to
    /// [`super::hash::wide64`] of the lane's key (golden-pinned).
    #[inline(always)]
    pub fn wide64(&self, i: usize) -> u64 {
        ((self.pairs[i].h1 as u64) << 32) | self.pairs[i].h2 as u64
    }
}

/// Indices of surviving rows, in ascending order — the unit every stage
/// of the vectorized pipeline passes downstream instead of cloned rows.
///
/// A probe fills it with the positions (into the probed key slice) that
/// *may* be members; the executor composes selections by gathering, so
/// repeated indices (one-to-many joins) are legal there even though a
/// filter probe never produces them.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SelectionVector {
    idx: Vec<u32>,
}

impl SelectionVector {
    pub fn new() -> Self {
        SelectionVector { idx: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        SelectionVector { idx: Vec::with_capacity(n) }
    }

    /// Reset to empty, keeping the allocation (probes reuse one buffer
    /// across partitions).
    pub fn clear(&mut self) {
        self.idx.clear();
    }

    #[inline]
    pub fn push(&mut self, i: u32) {
        self.idx.push(i);
    }

    pub fn len(&self) -> usize {
        self.idx.len()
    }

    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }

    pub fn indices(&self) -> &[u32] {
        &self.idx
    }

    /// Keep only the selected rows of an owned vector, in order — the
    /// zero-copy way to apply a probe result to the rows it was probed
    /// from.  Requires strictly ascending indices (what probes produce).
    pub fn gather_owned<T>(&self, rows: Vec<T>) -> Vec<T> {
        debug_assert!(self.idx.windows(2).all(|w| w[0] < w[1]), "selection not ascending");
        let mut out = Vec::with_capacity(self.idx.len());
        let mut want = self.idx.iter().copied();
        let mut next = want.next();
        for (i, row) in rows.into_iter().enumerate() {
            if next == Some(i as u32) {
                out.push(row);
                next = want.next();
            }
        }
        out
    }
}

/// Survivor mask with the low `len` bits set (a partial trailing chunk
/// starts with only its real lanes live).
#[inline]
pub(crate) fn live_mask(len: usize) -> u64 {
    if len >= 64 {
        u64::MAX
    } else {
        (1u64 << len) - 1
    }
}

/// Spill a chunk's survivor mask into the selection as absolute indices.
#[inline]
pub(crate) fn push_live(sel: &mut SelectionVector, chunk_no: usize, mut live: u64) {
    let base = (chunk_no * PROBE_CHUNK) as u32;
    while live != 0 {
        let i = live.trailing_zeros();
        live &= live - 1;
        sel.push(base + i);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selection_basics() {
        let mut s = SelectionVector::new();
        assert!(s.is_empty());
        s.push(0);
        s.push(5);
        assert_eq!(s.len(), 2);
        assert_eq!(s.indices(), &[0, 5]);
        s.clear();
        assert!(s.is_empty());
    }

    #[test]
    fn gather_owned_keeps_selected_rows_in_order() {
        let mut s = SelectionVector::new();
        for i in [1u32, 3, 4] {
            s.push(i);
        }
        assert_eq!(s.gather_owned(vec!["a", "b", "c", "d", "e"]), vec!["b", "d", "e"]);
        let empty = SelectionVector::new();
        assert!(empty.gather_owned(vec![1, 2, 3]).is_empty());
    }

    #[test]
    fn live_mask_shapes() {
        assert_eq!(live_mask(0), 0);
        assert_eq!(live_mask(3), 0b111);
        assert_eq!(live_mask(64), u64::MAX);
    }

    #[test]
    fn push_live_offsets_by_chunk() {
        let mut s = SelectionVector::new();
        push_live(&mut s, 1, 0b101);
        assert_eq!(s.indices(), &[64, 66]);
    }

    #[test]
    fn hashed_chunk_matches_scalar_hash() {
        let keys: Vec<u64> = (0..50u64).map(|i| i * 31 + 7).collect();
        let mut c = HashedChunk::new();
        c.fill(&keys);
        assert_eq!(c.len(), keys.len());
        for (i, &k) in keys.iter().enumerate() {
            assert_eq!(c.pair(i), HashPair::of_key(k));
            assert_eq!(c.wide64(i), crate::bloom::hash::wide64(k));
        }
    }

    /// The memoized path is pinned by the same golden vectors as the
    /// scalar `wide64` (mirrors python/tests/test_golden.py).
    #[test]
    fn hashed_chunk_golden_wide64_match_python() {
        let keys =
            [0u64, 1, 7, 42, 63, 64, 6_000_000, 123_456_789, 0xDEAD_BEEF, u64::MAX];
        let mut c = HashedChunk::new();
        c.fill(&keys);
        let want: [u64; 10] = [
            0x6E7B_9CBB_FC9F_F8FF,
            0xDC72_5748_FE6A_B465,
            0x0FB0_2A5B_FE10_52F1,
            0x2119_E8C3_B6ED_9779,
            0x6CB9_7E82_2DDA_3137,
            0x6CB7_3CCD_6585_6AC5,
            0xA76A_AA86_A693_F51F,
            0xADC5_5054_570A_4885,
            0xA613_3928_90A5_69E1,
            0x16F2_A371_CDF4_283B,
        ];
        for (i, w) in want.iter().enumerate() {
            assert_eq!(c.wide64(i), *w, "lane {i}");
        }
    }

    #[test]
    fn fill_live_hashes_only_live_lanes() {
        let keys: Vec<u64> = (0..8u64).collect();
        let mut c = HashedChunk::new();
        c.fill_live(&keys, 0b1010_1010);
        for i in [1usize, 3, 5, 7] {
            assert_eq!(c.pair(i), HashPair::of_key(keys[i]), "live lane {i} hashed");
        }
        for i in [0usize, 2, 4, 6] {
            assert_eq!(c.pair(i), HashPair { h1: 0, h2: 1 }, "dead lane {i} untouched");
        }
    }
}
