//! Batched membership probing: the selection-vector half of the
//! vectorized probe pipeline.
//!
//! The scalar probe path (`contains_key` in a per-row loop) recomputes
//! the hash pair, branches, and bumps an output vector one key at a
//! time.  The batched path hashes a whole chunk of [`PROBE_CHUNK`] keys
//! up front, keeps the chunk's survivors in one `u64` bitmask while the
//! `k` bit tests run position-major over the chunk, and only then spills
//! the surviving **row indices** into a reusable [`SelectionVector`] —
//! no per-key allocation, no cloned rows.  Downstream operators gather
//! through the selection instead of materialising survivor rows, which
//! is what makes the plan executor's hot path allocation-light.
//!
//! Every [`super::KeyFilter`] gets a default scalar `probe_batch`; the
//! three concrete filters override it with the chunked implementation
//! (see `filter.rs`, `blocked.rs`, `pagh.rs`).  The equivalence property
//! — `probe_batch` selects exactly the keys `contains` accepts — is
//! pinned by `rust/tests/probe_batch_equivalence.rs`.

/// Keys hashed per chunk: one `u64` survivor mask covers the chunk, so
/// the inner bit-test loop is branch-light and the mask early-exits as
/// soon as a chunk has no survivors left.
pub const PROBE_CHUNK: usize = 64;

/// Indices of surviving rows, in ascending order — the unit every stage
/// of the vectorized pipeline passes downstream instead of cloned rows.
///
/// A probe fills it with the positions (into the probed key slice) that
/// *may* be members; the executor composes selections by gathering, so
/// repeated indices (one-to-many joins) are legal there even though a
/// filter probe never produces them.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SelectionVector {
    idx: Vec<u32>,
}

impl SelectionVector {
    pub fn new() -> Self {
        SelectionVector { idx: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        SelectionVector { idx: Vec::with_capacity(n) }
    }

    /// Reset to empty, keeping the allocation (probes reuse one buffer
    /// across partitions).
    pub fn clear(&mut self) {
        self.idx.clear();
    }

    #[inline]
    pub fn push(&mut self, i: u32) {
        self.idx.push(i);
    }

    pub fn len(&self) -> usize {
        self.idx.len()
    }

    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }

    pub fn indices(&self) -> &[u32] {
        &self.idx
    }

    /// Keep only the selected rows of an owned vector, in order — the
    /// zero-copy way to apply a probe result to the rows it was probed
    /// from.  Requires strictly ascending indices (what probes produce).
    pub fn gather_owned<T>(&self, rows: Vec<T>) -> Vec<T> {
        debug_assert!(self.idx.windows(2).all(|w| w[0] < w[1]), "selection not ascending");
        let mut out = Vec::with_capacity(self.idx.len());
        let mut want = self.idx.iter().copied();
        let mut next = want.next();
        for (i, row) in rows.into_iter().enumerate() {
            if next == Some(i as u32) {
                out.push(row);
                next = want.next();
            }
        }
        out
    }
}

/// Survivor mask with the low `len` bits set (a partial trailing chunk
/// starts with only its real lanes live).
#[inline]
pub(crate) fn live_mask(len: usize) -> u64 {
    if len >= 64 {
        u64::MAX
    } else {
        (1u64 << len) - 1
    }
}

/// Spill a chunk's survivor mask into the selection as absolute indices.
#[inline]
pub(crate) fn push_live(sel: &mut SelectionVector, chunk_no: usize, mut live: u64) {
    let base = (chunk_no * PROBE_CHUNK) as u32;
    while live != 0 {
        let i = live.trailing_zeros();
        live &= live - 1;
        sel.push(base + i);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selection_basics() {
        let mut s = SelectionVector::new();
        assert!(s.is_empty());
        s.push(0);
        s.push(5);
        assert_eq!(s.len(), 2);
        assert_eq!(s.indices(), &[0, 5]);
        s.clear();
        assert!(s.is_empty());
    }

    #[test]
    fn gather_owned_keeps_selected_rows_in_order() {
        let mut s = SelectionVector::new();
        for i in [1u32, 3, 4] {
            s.push(i);
        }
        assert_eq!(s.gather_owned(vec!["a", "b", "c", "d", "e"]), vec!["b", "d", "e"]);
        let empty = SelectionVector::new();
        assert!(empty.gather_owned(vec![1, 2, 3]).is_empty());
    }

    #[test]
    fn live_mask_shapes() {
        assert_eq!(live_mask(0), 0);
        assert_eq!(live_mask(3), 0b111);
        assert_eq!(live_mask(64), u64::MAX);
    }

    #[test]
    fn push_live_offsets_by_chunk() {
        let mut s = SelectionVector::new();
        push_live(&mut s, 1, 0b101);
        assert_eq!(s.indices(), &[64, 66]);
    }
}
