//! Hash algebra shared with the JAX/Pallas kernels.
//!
//! Single source of truth is `python/compile/kernels/hashing.py`; this file
//! re-implements it for the native probe path and is pinned against the
//! same golden vectors (`python/tests/test_golden.py`).  If either side
//! drifts, both test suites fail.
//!
//! Scheme: 64-bit join keys are folded to u32 with splitmix64 (high word),
//! then double hashing `pos_j = (h1 + j*h2) mod m` with murmur3 `fmix32`
//! under two salts, `h2` forced odd, `m` a power of two.

/// Salt for the first hash stream (golden ratio).
pub const C1: u32 = 0x9E37_79B9;
/// Salt for the second hash stream (murmur constant).
pub const C2: u32 = 0x85EB_CA77;
/// Max hash functions any probe path supports (kernel lane count).
pub const K_MAX: usize = 16;

/// murmur3 fmix32 finalizer — full-avalanche 32-bit permutation.
#[inline(always)]
pub fn mix32(mut x: u32) -> u32 {
    x ^= x >> 16;
    x = x.wrapping_mul(0x85EB_CA6B);
    x ^= x >> 13;
    x = x.wrapping_mul(0xC2B2_AE35);
    x ^= x >> 16;
    x
}

/// Fold a 64-bit key to the u32 the kernels consume: splitmix64 high word.
#[inline(always)]
pub fn fold64(key: u64) -> u32 {
    let mut z = key.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    ((z ^ (z >> 31)) >> 32) as u32
}

/// The double-hash pair for a folded key.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HashPair {
    pub h1: u32,
    /// Always odd, so the probe stride is a unit of Z/2^t Z.
    pub h2: u32,
}

impl HashPair {
    #[inline(always)]
    pub fn of_folded(kf: u32) -> Self {
        HashPair { h1: mix32(kf ^ C1), h2: mix32(kf ^ C2) | 1 }
    }

    #[inline(always)]
    pub fn of_key(key: u64) -> Self {
        Self::of_folded(fold64(key))
    }

    /// j-th probe position in a filter of `m_bits` (power of two).
    #[inline(always)]
    pub fn position(&self, j: u32, m_mask: u32) -> u32 {
        self.h1.wrapping_add(j.wrapping_mul(self.h2)) & m_mask
    }
}

/// The packed 64-bit hash word for quotienting structures
/// ([`crate::bloom::pagh`]): the double-hash pair with `h1` in the high
/// word and the odd `h2` low.  Same algebra as the kernels (mirrored by
/// `wide64_py` in `python/compile/kernels/hashing.py`), pinned by the
/// golden vectors below — one hash source of truth across every filter.
#[inline(always)]
pub fn wide64(key: u64) -> u64 {
    let hp = HashPair::of_key(key);
    ((hp.h1 as u64) << 32) | hp.h2 as u64
}

/// All `k` probe positions for a folded key (test/reference helper).
pub fn probe_positions(kf: u32, m_bits: u64, k: usize) -> Vec<u32> {
    debug_assert!(m_bits.is_power_of_two());
    let mask = (m_bits - 1) as u32;
    let hp = HashPair::of_folded(kf);
    (0..k as u32).map(|j| hp.position(j, mask)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Mirrors python/tests/test_golden.py::GOLDEN_POSITIONS exactly.
    #[test]
    fn golden_positions_match_python() {
        assert_eq!(probe_positions(0, 1 << 17, 4), vec![12046, 81955, 20792, 90701]);
        assert_eq!(probe_positions(1, 1 << 17, 4), vec![46339, 24664, 2989, 112386]);
        assert_eq!(
            probe_positions(42, 1 << 19, 6),
            vec![126672, 304003, 481334, 134377, 311708, 489039]
        );
        assert_eq!(
            probe_positions(0xDEAD_BEEF, 1 << 21, 8),
            vec![965299, 1919236, 776021, 1729958, 586743, 1540680, 397465, 1351402]
        );
        assert_eq!(
            probe_positions(0xFFFF_FFFF, 1 << 25, 3),
            vec![23507626, 1190431, 12427668]
        );
    }

    /// Mirrors python/tests/test_golden.py::GOLDEN_FOLD64 exactly.
    #[test]
    fn golden_fold64_match_python() {
        assert_eq!(fold64(0), 0xE220_A839);
        assert_eq!(fold64(1), 0x910A_2DEC);
        assert_eq!(fold64(6_000_000), 0x810B_E29C);
        assert_eq!(fold64(u64::MAX), 0xE4D9_7177);
    }

    /// Mirrors python/tests/test_golden.py::GOLDEN_WIDE64 exactly.  The
    /// memoized chunk path (`HashedChunk::wide64`) is pinned against the
    /// same table in `bloom/batch.rs`, so the hash cache cannot drift
    /// from this scalar source of truth.
    #[test]
    fn golden_wide64_match_python() {
        assert_eq!(wide64(0), 0x6E7B_9CBB_FC9F_F8FF);
        assert_eq!(wide64(1), 0xDC72_5748_FE6A_B465);
        assert_eq!(wide64(7), 0x0FB0_2A5B_FE10_52F1);
        assert_eq!(wide64(42), 0x2119_E8C3_B6ED_9779);
        assert_eq!(wide64(63), 0x6CB9_7E82_2DDA_3137);
        assert_eq!(wide64(64), 0x6CB7_3CCD_6585_6AC5);
        assert_eq!(wide64(6_000_000), 0xA76A_AA86_A693_F51F);
        assert_eq!(wide64(123_456_789), 0xADC5_5054_570A_4885);
        assert_eq!(wide64(0xDEAD_BEEF), 0xA613_3928_90A5_69E1);
        assert_eq!(wide64(u64::MAX), 0x16F2_A371_CDF4_283B);
    }

    #[test]
    fn wide64_packs_the_hash_pair() {
        for key in [0u64, 7, 0xDEAD_BEEF, u64::MAX] {
            let hp = HashPair::of_key(key);
            let w = wide64(key);
            assert_eq!((w >> 32) as u32, hp.h1);
            assert_eq!(w as u32, hp.h2);
            assert_eq!(w & 1, 1, "low word is the odd h2");
        }
    }

    #[test]
    fn h2_is_always_odd() {
        for k in [0u32, 1, 2, 3, 0xFFFF_FFFF, 0x1234_5678] {
            assert_eq!(HashPair::of_folded(k).h2 & 1, 1);
        }
    }

    #[test]
    fn positions_within_mask() {
        for key in 0..1000u64 {
            let hp = HashPair::of_key(key);
            for j in 0..K_MAX as u32 {
                assert!(hp.position(j, (1 << 17) - 1) < (1 << 17));
            }
        }
    }

    #[test]
    fn mix32_avalanche_smoke() {
        // flipping one input bit flips ~half the output bits on average
        let mut total = 0u32;
        let trials = 1000;
        for i in 0..trials {
            let a = mix32(i);
            let b = mix32(i ^ 1);
            total += (a ^ b).count_ones();
        }
        let avg = total as f64 / trials as f64;
        assert!((avg - 16.0).abs() < 2.0, "avalanche avg {avg}");
    }

    #[test]
    fn distinct_keys_rarely_share_pair() {
        use std::collections::HashSet;
        let mut seen = HashSet::new();
        for key in 0..10_000u64 {
            let hp = HashPair::of_key(key);
            assert!(seen.insert((hp.h1, hp.h2)), "pair collision at {key}");
        }
    }
}
