//! Bloom filters: the paper's core data structure.
//!
//! * [`hash`] — the hash algebra shared bit-for-bit with the Pallas kernel
//!   (`python/compile/kernels/hashing.py`), pinned by golden vectors.
//! * [`filter`] — the standard partitioned-build/OR-merge filter with the
//!   paper's optimal sizing `m ≈ n·1.44·log2(1/ε)` (§7.1.1).
//! * [`blocked`] — cache-line-blocked variant (one line per key), an
//!   ablation for probe locality.
//! * [`pagh`] — a compact single-hash-function filter after Pagh, Pagh &
//!   Rao 2005, the "possible optimisation we did not explore" the paper
//!   cites (space factor ~1 instead of 1.44).
//! * [`batch`] — the batched probe API ([`SelectionVector`] +
//!   `probe_batch`): chunk-at-a-time membership tests that feed the
//!   vectorized plan executor instead of per-key `contains_key` loops.

pub mod batch;
pub mod blocked;
pub mod filter;
pub mod hash;
pub mod pagh;

pub use batch::{HashedChunk, SelectionVector, PROBE_CHUNK};
pub use blocked::BlockedBloomFilter;
pub use filter::{BloomFilter, BloomParams};
pub use hash::{fold64, probe_positions, wide64, HashPair};
pub use pagh::PaghFilter;

/// Common probe interface so joins and benches can swap filter kinds.
pub trait KeyFilter {
    /// May return false positives, never false negatives.
    fn contains(&self, key: u64) -> bool;

    /// Size of the structure in bits (for the cost model / metrics).
    fn size_bits(&self) -> u64;

    /// Batched membership: overwrite `sel` with the (ascending) indices
    /// of the keys that may be members.  The default is the scalar loop;
    /// every concrete filter overrides it with a chunked implementation
    /// that hashes [`PROBE_CHUNK`] keys up front and tests positions
    /// chunk-at-a-time.  Must select exactly the keys [`Self::contains`]
    /// accepts (property-tested in `rust/tests/probe_batch_equivalence.rs`).
    fn probe_batch(&self, keys: &[u64], sel: &mut SelectionVector) {
        sel.clear();
        for (i, &k) in keys.iter().enumerate() {
            if self.contains(k) {
                sel.push(i as u32);
            }
        }
    }
}
