//! Simulated distributed file system (the paper's HDFS).
//!
//! Files are sequences of fixed-size blocks placed round-robin (with
//! replication) across nodes.  Reads report whether they were node-local,
//! which the cluster's network model prices: the paper's 128 MB-CSV split
//! convention (§6.1) is what decides how many scan tasks a table produces.

use std::collections::BTreeMap;

/// 128 MiB, the Spark/HDFS default split the paper kept.
pub const DEFAULT_BLOCK_SIZE: u64 = 128 * 1024 * 1024;

#[derive(Clone, Debug)]
pub struct DfsConfig {
    pub block_size: u64,
    pub replication: usize,
    pub n_nodes: usize,
}

impl Default for DfsConfig {
    fn default() -> Self {
        DfsConfig { block_size: DEFAULT_BLOCK_SIZE, replication: 3, n_nodes: 4 }
    }
}

#[derive(Clone, Debug)]
pub struct Block {
    pub data: Vec<u8>,
    /// Nodes holding a replica, primary first.
    pub replicas: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct DfsFile {
    pub blocks: Vec<Block>,
    pub len: u64,
}

/// In-memory DFS: path → file.
pub struct SimDfs {
    cfg: DfsConfig,
    files: BTreeMap<String, DfsFile>,
    next_primary: usize,
}

#[derive(Debug)]
pub enum DfsError {
    NotFound(String),
    Exists(String),
}

impl std::fmt::Display for DfsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DfsError::NotFound(path) => write!(f, "no such file: {path}"),
            DfsError::Exists(path) => write!(f, "file exists: {path}"),
        }
    }
}

impl std::error::Error for DfsError {}

impl SimDfs {
    pub fn new(cfg: DfsConfig) -> Self {
        assert!(cfg.n_nodes >= 1 && cfg.replication >= 1);
        SimDfs { cfg, files: BTreeMap::new(), next_primary: 0 }
    }

    pub fn config(&self) -> &DfsConfig {
        &self.cfg
    }

    /// Write a file, splitting into blocks and placing replicas.
    pub fn put(&mut self, path: &str, data: &[u8]) -> Result<(), DfsError> {
        if self.files.contains_key(path) {
            return Err(DfsError::Exists(path.to_string()));
        }
        let bs = self.cfg.block_size as usize;
        let mut blocks = Vec::new();
        let chunks: Vec<&[u8]> = if data.is_empty() {
            vec![&[][..]]
        } else {
            data.chunks(bs).collect()
        };
        for chunk in chunks {
            let primary = self.next_primary % self.cfg.n_nodes;
            self.next_primary += 1;
            let replicas: Vec<usize> = (0..self.cfg.replication.min(self.cfg.n_nodes))
                .map(|r| (primary + r) % self.cfg.n_nodes)
                .collect();
            blocks.push(Block { data: chunk.to_vec(), replicas });
        }
        self.files.insert(path.to_string(), DfsFile { blocks, len: data.len() as u64 });
        Ok(())
    }

    /// Whole-file read (driver-side convenience).
    pub fn get(&self, path: &str) -> Result<Vec<u8>, DfsError> {
        let f = self.files.get(path).ok_or_else(|| DfsError::NotFound(path.to_string()))?;
        let mut out = Vec::with_capacity(f.len as usize);
        for b in &f.blocks {
            out.extend_from_slice(&b.data);
        }
        Ok(out)
    }

    /// Read one block from `node`'s perspective; returns (bytes, local?).
    pub fn read_block(&self, path: &str, idx: usize, node: usize) -> Result<(&[u8], bool), DfsError> {
        let f = self.files.get(path).ok_or_else(|| DfsError::NotFound(path.to_string()))?;
        let b = f
            .blocks
            .get(idx)
            .ok_or_else(|| DfsError::NotFound(format!("{path}#{idx}")))?;
        Ok((&b.data, b.replicas.contains(&node)))
    }

    pub fn n_blocks(&self, path: &str) -> Result<usize, DfsError> {
        Ok(self
            .files
            .get(path)
            .ok_or_else(|| DfsError::NotFound(path.to_string()))?
            .blocks
            .len())
    }

    pub fn len(&self, path: &str) -> Result<u64, DfsError> {
        Ok(self.files.get(path).ok_or_else(|| DfsError::NotFound(path.to_string()))?.len)
    }

    pub fn is_empty(&self) -> bool {
        self.files.is_empty()
    }

    pub fn ls(&self) -> Vec<&str> {
        self.files.keys().map(|s| s.as_str()).collect()
    }

    /// Preferred node for a scan task over block `idx` (primary replica) —
    /// the locality hint a YARN-like scheduler consumes.
    pub fn preferred_node(&self, path: &str, idx: usize) -> Result<usize, DfsError> {
        let f = self.files.get(path).ok_or_else(|| DfsError::NotFound(path.to_string()))?;
        Ok(f.blocks[idx].replicas[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dfs(block: u64) -> SimDfs {
        SimDfs::new(DfsConfig { block_size: block, replication: 2, n_nodes: 4 })
    }

    #[test]
    fn roundtrip_small_and_multiblock() {
        let mut d = dfs(8);
        let data: Vec<u8> = (0..50u8).collect();
        d.put("t/orders", &data).unwrap();
        assert_eq!(d.get("t/orders").unwrap(), data);
        assert_eq!(d.n_blocks("t/orders").unwrap(), 7); // ceil(50/8)
        assert_eq!(d.len("t/orders").unwrap(), 50);
    }

    #[test]
    fn replication_and_placement() {
        let mut d = dfs(4);
        d.put("f", &[0u8; 16]).unwrap();
        for i in 0..4 {
            let (_, _) = d.read_block("f", i, 0).unwrap();
            let pref = d.preferred_node("f", i).unwrap();
            assert!(pref < 4);
            // primary rotates round-robin
            assert_eq!(pref, i % 4);
        }
    }

    #[test]
    fn locality_flag() {
        let mut d = dfs(4);
        d.put("f", &[1u8; 4]).unwrap();
        let pref = d.preferred_node("f", 0).unwrap();
        let (_, local) = d.read_block("f", 0, pref).unwrap();
        assert!(local);
        let far = (pref + 2) % 4; // replication=2 → pref and pref+1 are local
        let (_, local) = d.read_block("f", 0, far).unwrap();
        assert!(!local);
    }

    #[test]
    fn errors() {
        let mut d = dfs(4);
        assert!(matches!(d.get("nope"), Err(DfsError::NotFound(_))));
        d.put("f", &[]).unwrap();
        assert!(matches!(d.put("f", &[]), Err(DfsError::Exists(_))));
        assert_eq!(d.get("f").unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn empty_file_has_one_empty_block() {
        let mut d = dfs(4);
        d.put("e", &[]).unwrap();
        assert_eq!(d.n_blocks("e").unwrap(), 1);
    }
}
