//! dbgen `.tbl` text codec ('|'-separated, one trailing '|'), the CSV
//! interchange format of the paper's pipeline (CSV → Parquet → HDFS).
//! Dates render as yyyy-mm-dd like dbgen's output.

use crate::tpch::{Customer, Lineitem, Order, MKT_SEGMENTS, SHIP_MODES};

/// Days since 1992-01-01 → "yyyy-mm-dd".
pub fn render_date(days: i32) -> String {
    // civil-date arithmetic (Howard Hinnant's algorithm), anchored at
    // 1992-01-01 = day 0  (1992-01-01 is 8035 days after 1970-01-01).
    let z = days as i64 + 8035 + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097);
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = doy - (153 * mp + 2) / 5 + 1;
    let m = if mp < 10 { mp + 3 } else { mp - 9 };
    let y = if m <= 2 { y + 1 } else { y };
    format!("{y:04}-{m:02}-{d:02}")
}

/// "yyyy-mm-dd" → days since 1992-01-01.
pub fn parse_date(s: &str) -> Option<i32> {
    let mut it = s.split('-');
    let y: i64 = it.next()?.parse().ok()?;
    let m: i64 = it.next()?.parse().ok()?;
    let d: i64 = it.next()?.parse().ok()?;
    if it.next().is_some() || !(1..=12).contains(&m) || !(1..=31).contains(&d) {
        return None;
    }
    let y2 = if m <= 2 { y - 1 } else { y };
    let era = y2.div_euclid(400);
    let yoe = y2 - era * 400;
    let mp = if m > 2 { m - 3 } else { m + 9 };
    let doy = (153 * mp + 2) / 5 + d - 1;
    let doe = yoe * 365 + yoe / 4 - yoe / 100 + doy;
    Some((era * 146_097 + doe - 719_468 - 8035) as i32)
}

fn money(cents: i64) -> String {
    format!("{}.{:02}", cents / 100, (cents % 100).abs())
}

fn parse_money(s: &str) -> Option<i64> {
    let (int, frac) = s.split_once('.')?;
    let sign = if int.starts_with('-') { -1 } else { 1 };
    let int: i64 = int.parse().ok()?;
    let frac: i64 = frac.parse().ok()?;
    Some(int * 100 + sign * frac)
}

pub trait TblCodec: Sized {
    fn to_tbl_line(&self) -> String;
    fn from_tbl_line(line: &str) -> Option<Self>;

    fn write_all(rows: &[Self]) -> String {
        rows.iter().map(|r| r.to_tbl_line()).collect()
    }

    fn read_all(text: &str) -> Result<Vec<Self>, String> {
        text.lines()
            .filter(|l| !l.is_empty())
            .enumerate()
            .map(|(i, l)| Self::from_tbl_line(l).ok_or_else(|| format!("line {}: {l:?}", i + 1)))
            .collect()
    }
}

impl TblCodec for Order {
    fn to_tbl_line(&self) -> String {
        format!(
            "{}|{}|{}|{}|{}|{}-{}|Clerk#{:09}|{}|{}|\n",
            self.o_orderkey,
            self.o_custkey,
            self.o_orderstatus as char,
            money(self.o_totalprice_cents),
            render_date(self.o_orderdate),
            self.o_orderpriority,
            priority_name(self.o_orderpriority),
            self.o_clerk,
            self.o_shippriority,
            self.o_comment
        )
    }

    fn from_tbl_line(line: &str) -> Option<Self> {
        let f: Vec<&str> = line.trim_end_matches('\n').split('|').collect();
        if f.len() < 9 {
            return None;
        }
        Some(Order {
            o_orderkey: f[0].parse().ok()?,
            o_custkey: f[1].parse().ok()?,
            o_orderstatus: *f[2].as_bytes().first()?,
            o_totalprice_cents: parse_money(f[3])?,
            o_orderdate: parse_date(f[4])?,
            o_orderpriority: f[5].split('-').next()?.parse().ok()?,
            o_clerk: f[6].strip_prefix("Clerk#")?.parse().ok()?,
            o_shippriority: f[7].parse().ok()?,
            o_comment: f[8].to_string(),
        })
    }
}

fn priority_name(p: u8) -> &'static str {
    match p {
        1 => "URGENT",
        2 => "HIGH",
        3 => "MEDIUM",
        4 => "NOT SPECIFIED",
        _ => "LOW",
    }
}

impl TblCodec for Lineitem {
    fn to_tbl_line(&self) -> String {
        format!(
            "{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|NONE|{}|{}|\n",
            self.l_orderkey,
            self.l_partkey,
            self.l_suppkey,
            self.l_linenumber,
            self.l_quantity,
            money(self.l_extendedprice_cents),
            format_args!("0.{:02}", self.l_discount_bp / 10),
            format_args!("0.{:02}", self.l_tax_bp / 10),
            self.l_returnflag as char,
            self.l_linestatus as char,
            render_date(self.l_shipdate),
            render_date(self.l_commitdate),
            render_date(self.l_receiptdate),
            SHIP_MODES[self.l_shipmode as usize],
            self.l_comment
        )
    }

    fn from_tbl_line(line: &str) -> Option<Self> {
        let f: Vec<&str> = line.trim_end_matches('\n').split('|').collect();
        if f.len() < 16 {
            return None;
        }
        let mode = SHIP_MODES.iter().position(|m| *m == f[14])? as u8;
        Some(Lineitem {
            l_orderkey: f[0].parse().ok()?,
            l_partkey: f[1].parse().ok()?,
            l_suppkey: f[2].parse().ok()?,
            l_linenumber: f[3].parse().ok()?,
            l_quantity: f[4].parse().ok()?,
            l_extendedprice_cents: parse_money(f[5])?,
            l_discount_bp: f[6].strip_prefix("0.")?.parse::<i32>().ok()? * 10,
            l_tax_bp: f[7].strip_prefix("0.")?.parse::<i32>().ok()? * 10,
            l_returnflag: *f[8].as_bytes().first()?,
            l_linestatus: *f[9].as_bytes().first()?,
            l_shipdate: parse_date(f[10])?,
            l_commitdate: parse_date(f[11])?,
            l_receiptdate: parse_date(f[12])?,
            l_shipmode: mode,
            l_comment: f[15].to_string(),
        })
    }
}

impl TblCodec for Customer {
    fn to_tbl_line(&self) -> String {
        format!(
            "{}|{}|{}|{}|{}|{}|\n",
            self.c_custkey,
            self.c_name,
            self.c_nationkey,
            money(self.c_acctbal_cents),
            MKT_SEGMENTS[self.c_mktsegment as usize],
            self.c_comment
        )
    }

    fn from_tbl_line(line: &str) -> Option<Self> {
        let f: Vec<&str> = line.trim_end_matches('\n').split('|').collect();
        if f.len() < 6 {
            return None;
        }
        Some(Customer {
            c_custkey: f[0].parse().ok()?,
            c_name: f[1].to_string(),
            c_nationkey: f[2].parse().ok()?,
            c_acctbal_cents: parse_money(f[3])?,
            c_mktsegment: MKT_SEGMENTS.iter().position(|m| *m == f[4])? as u8,
            c_comment: f[5].to_string(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tpch::{GenConfig, TpchGenerator};

    #[test]
    fn date_roundtrip() {
        for d in [0, 1, 31, 365, 366, 1263, 2252, 2405, 2555] {
            assert_eq!(parse_date(&render_date(d)), Some(d), "day {d}");
        }
        assert_eq!(render_date(0), "1992-01-01");
        assert_eq!(render_date(2252), "1998-03-02");
        assert_eq!(render_date(2405), "1998-08-02");
    }

    #[test]
    fn date_known_values() {
        assert_eq!(render_date(59), "1992-02-29"); // 1992 is a leap year
        assert_eq!(render_date(60), "1992-03-01");
        assert_eq!(parse_date("1995-06-17"), Some(1263));
    }

    #[test]
    fn money_roundtrip() {
        for c in [0i64, 1, 99, 100, 12_345, -250] {
            assert_eq!(parse_money(&money(c)), Some(c), "{c}");
        }
    }

    #[test]
    fn tbl_roundtrip_all_tables() {
        let g = TpchGenerator::new(GenConfig { sf: 0.0002, ..Default::default() });
        let orders: Vec<Order> = g.orders().into_iter().flatten().collect();
        let text = Order::write_all(&orders);
        assert_eq!(Order::read_all(&text).unwrap(), orders);

        let items: Vec<Lineitem> = g.lineitems().into_iter().flatten().collect();
        // discount/tax lose sub-0.1% precision in text (2 decimals) — the
        // same loss dbgen's fixed-point format has; normalise and compare.
        let text = Lineitem::write_all(&items);
        let back = Lineitem::read_all(&text).unwrap();
        assert_eq!(back.len(), items.len());
        for (a, b) in back.iter().zip(&items) {
            assert_eq!(a.l_orderkey, b.l_orderkey);
            assert_eq!(a.l_extendedprice_cents, b.l_extendedprice_cents);
            assert_eq!(a.l_shipdate, b.l_shipdate);
            assert!((a.l_discount_bp - b.l_discount_bp).abs() < 10);
        }

        let cust: Vec<Customer> = g.customers().into_iter().flatten().collect();
        let text = Customer::write_all(&cust);
        assert_eq!(Customer::read_all(&text).unwrap(), cust);
    }

    #[test]
    fn malformed_lines_rejected() {
        assert!(Order::from_tbl_line("1|2|3").is_none());
        assert!(Order::read_all("garbage|\n").is_err());
    }
}
