//! Storage substrate: a columnar row-group format ("mini-parquet"), the
//! dbgen `.tbl` text codec, and a simulated distributed file system with
//! 128 MB-equivalent splits and block placement (the paper's HDFS, §6.1).

pub mod columnar;
pub mod dfs;
pub mod tbl;

pub use columnar::{ColumnarCodec, RowGroup};
pub use dfs::{DfsConfig, SimDfs};
