//! Columnar row-group codec — the "Parquet with Spark defaults" of the
//! paper (§6.1): tables are split into row groups, each storing columns
//! contiguously with lightweight encodings (delta for sorted keys, dict
//! for low-cardinality bytes, raw LE otherwise).  Enough structure to make
//! scan cost ∝ bytes-read realistic, without a full Parquet reader.

use crate::tpch::{Customer, Lineitem, Order};

/// One encoded row group.
#[derive(Clone, Debug, PartialEq)]
pub struct RowGroup {
    pub n_rows: u32,
    pub bytes: Vec<u8>,
}

impl RowGroup {
    pub fn encoded_len(&self) -> u64 {
        self.bytes.len() as u64
    }
}

/// Encode/decode a table type to row groups.
pub trait ColumnarCodec: Sized {
    fn encode_group(rows: &[Self]) -> RowGroup;
    fn decode_group(group: &RowGroup) -> Result<Vec<Self>, CodecError>;

    /// Split into row groups of at most `rows_per_group`.
    fn encode(rows: &[Self], rows_per_group: usize) -> Vec<RowGroup> {
        rows.chunks(rows_per_group.max(1)).map(Self::encode_group).collect()
    }

    fn decode(groups: &[RowGroup]) -> Result<Vec<Self>, CodecError> {
        let mut out = Vec::new();
        for g in groups {
            out.extend(Self::decode_group(g)?);
        }
        Ok(out)
    }
}

#[derive(Debug)]
pub enum CodecError {
    Truncated { at: usize, wanted: usize },
    BadUtf8,
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecError::Truncated { at, wanted } => {
                write!(f, "row group truncated (wanted {wanted} more bytes at {at})")
            }
            CodecError::BadUtf8 => write!(f, "invalid utf-8 in string column"),
        }
    }
}

impl std::error::Error for CodecError {}

// --- primitive writers/readers ---------------------------------------------

struct W(Vec<u8>);

impl W {
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn i32(&mut self, v: i32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn i64(&mut self, v: i64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    /// varint-delta encode a non-decreasing u64 column (orderkeys).
    fn delta_u64(&mut self, vs: impl Iterator<Item = u64>) {
        let mut last = 0u64;
        for v in vs {
            let d = v.wrapping_sub(last);
            last = v;
            self.varint(d);
        }
    }
    fn varint(&mut self, mut v: u64) {
        loop {
            let b = (v & 0x7F) as u8;
            v >>= 7;
            if v == 0 {
                self.0.push(b);
                break;
            }
            self.0.push(b | 0x80);
        }
    }
    fn strs<'a>(&mut self, vs: impl Iterator<Item = &'a str>) {
        for s in vs {
            self.varint(s.len() as u64);
            self.0.extend_from_slice(s.as_bytes());
        }
    }
}

struct R<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> R<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        if self.pos + n > self.b.len() {
            return Err(CodecError::Truncated { at: self.pos, wanted: n });
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32, CodecError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, CodecError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn i32(&mut self) -> Result<i32, CodecError> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn i64(&mut self) -> Result<i64, CodecError> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn varint(&mut self) -> Result<u64, CodecError> {
        let mut v = 0u64;
        let mut shift = 0;
        loop {
            let b = *self
                .b
                .get(self.pos)
                .ok_or(CodecError::Truncated { at: self.pos, wanted: 1 })?;
            self.pos += 1;
            v |= ((b & 0x7F) as u64) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }
    fn delta_u64(&mut self, n: usize) -> Result<Vec<u64>, CodecError> {
        let mut out = Vec::with_capacity(n);
        let mut last = 0u64;
        for _ in 0..n {
            last = last.wrapping_add(self.varint()?);
            out.push(last);
        }
        Ok(out)
    }
    fn strs(&mut self, n: usize) -> Result<Vec<String>, CodecError> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let len = self.varint()? as usize;
            let s = std::str::from_utf8(self.take(len)?).map_err(|_| CodecError::BadUtf8)?;
            out.push(s.to_string());
        }
        Ok(out)
    }
}

// --- Order ------------------------------------------------------------------

impl ColumnarCodec for Order {
    fn encode_group(rows: &[Self]) -> RowGroup {
        let mut w = W(Vec::with_capacity(rows.len() * 40));
        w.delta_u64(rows.iter().map(|r| r.o_orderkey));
        for r in rows {
            w.u64(r.o_custkey);
        }
        w.0.extend(rows.iter().map(|r| r.o_orderstatus));
        for r in rows {
            w.i64(r.o_totalprice_cents);
        }
        for r in rows {
            w.i32(r.o_orderdate);
        }
        w.0.extend(rows.iter().map(|r| r.o_orderpriority));
        for r in rows {
            w.u32(r.o_clerk);
        }
        for r in rows {
            w.i32(r.o_shippriority);
        }
        w.strs(rows.iter().map(|r| r.o_comment.as_str()));
        RowGroup { n_rows: rows.len() as u32, bytes: w.0 }
    }

    fn decode_group(group: &RowGroup) -> Result<Vec<Self>, CodecError> {
        let n = group.n_rows as usize;
        let mut r = R { b: &group.bytes, pos: 0 };
        let orderkeys = r.delta_u64(n)?;
        let custkeys: Vec<u64> = (0..n).map(|_| r.u64()).collect::<Result<_, _>>()?;
        let status = r.take(n)?.to_vec();
        let totals: Vec<i64> = (0..n).map(|_| r.i64()).collect::<Result<_, _>>()?;
        let dates: Vec<i32> = (0..n).map(|_| r.i32()).collect::<Result<_, _>>()?;
        let prio = r.take(n)?.to_vec();
        let clerks: Vec<u32> = (0..n).map(|_| r.u32()).collect::<Result<_, _>>()?;
        let shipprio: Vec<i32> = (0..n).map(|_| r.i32()).collect::<Result<_, _>>()?;
        let comments = r.strs(n)?;
        Ok((0..n)
            .map(|i| Order {
                o_orderkey: orderkeys[i],
                o_custkey: custkeys[i],
                o_orderstatus: status[i],
                o_totalprice_cents: totals[i],
                o_orderdate: dates[i],
                o_orderpriority: prio[i],
                o_clerk: clerks[i],
                o_shippriority: shipprio[i],
                o_comment: comments[i].clone(),
            })
            .collect())
    }
}

// --- Lineitem ----------------------------------------------------------------

impl ColumnarCodec for Lineitem {
    fn encode_group(rows: &[Self]) -> RowGroup {
        let mut w = W(Vec::with_capacity(rows.len() * 56));
        w.delta_u64(rows.iter().map(|r| r.l_orderkey));
        for r in rows {
            w.u64(r.l_partkey);
        }
        for r in rows {
            w.u64(r.l_suppkey);
        }
        for r in rows {
            w.i32(r.l_linenumber);
        }
        for r in rows {
            w.i32(r.l_quantity);
        }
        for r in rows {
            w.i64(r.l_extendedprice_cents);
        }
        for r in rows {
            w.i32(r.l_discount_bp);
        }
        for r in rows {
            w.i32(r.l_tax_bp);
        }
        w.0.extend(rows.iter().map(|r| r.l_returnflag));
        w.0.extend(rows.iter().map(|r| r.l_linestatus));
        for r in rows {
            w.i32(r.l_shipdate);
        }
        for r in rows {
            w.i32(r.l_commitdate);
        }
        for r in rows {
            w.i32(r.l_receiptdate);
        }
        w.0.extend(rows.iter().map(|r| r.l_shipmode));
        w.strs(rows.iter().map(|r| r.l_comment.as_str()));
        RowGroup { n_rows: rows.len() as u32, bytes: w.0 }
    }

    fn decode_group(group: &RowGroup) -> Result<Vec<Self>, CodecError> {
        let n = group.n_rows as usize;
        let mut r = R { b: &group.bytes, pos: 0 };
        let orderkeys = r.delta_u64(n)?;
        let partkeys: Vec<u64> = (0..n).map(|_| r.u64()).collect::<Result<_, _>>()?;
        let suppkeys: Vec<u64> = (0..n).map(|_| r.u64()).collect::<Result<_, _>>()?;
        let linenos: Vec<i32> = (0..n).map(|_| r.i32()).collect::<Result<_, _>>()?;
        let qtys: Vec<i32> = (0..n).map(|_| r.i32()).collect::<Result<_, _>>()?;
        let prices: Vec<i64> = (0..n).map(|_| r.i64()).collect::<Result<_, _>>()?;
        let discs: Vec<i32> = (0..n).map(|_| r.i32()).collect::<Result<_, _>>()?;
        let taxes: Vec<i32> = (0..n).map(|_| r.i32()).collect::<Result<_, _>>()?;
        let rflags = r.take(n)?.to_vec();
        let lstatus = r.take(n)?.to_vec();
        let ship: Vec<i32> = (0..n).map(|_| r.i32()).collect::<Result<_, _>>()?;
        let commit: Vec<i32> = (0..n).map(|_| r.i32()).collect::<Result<_, _>>()?;
        let receipt: Vec<i32> = (0..n).map(|_| r.i32()).collect::<Result<_, _>>()?;
        let modes = r.take(n)?.to_vec();
        let comments = r.strs(n)?;
        Ok((0..n)
            .map(|i| Lineitem {
                l_orderkey: orderkeys[i],
                l_partkey: partkeys[i],
                l_suppkey: suppkeys[i],
                l_linenumber: linenos[i],
                l_quantity: qtys[i],
                l_extendedprice_cents: prices[i],
                l_discount_bp: discs[i],
                l_tax_bp: taxes[i],
                l_returnflag: rflags[i],
                l_linestatus: lstatus[i],
                l_shipdate: ship[i],
                l_commitdate: commit[i],
                l_receiptdate: receipt[i],
                l_shipmode: modes[i],
                l_comment: comments[i].clone(),
            })
            .collect())
    }
}

// --- Customer ------------------------------------------------------------------

impl ColumnarCodec for Customer {
    fn encode_group(rows: &[Self]) -> RowGroup {
        let mut w = W(Vec::with_capacity(rows.len() * 48));
        w.delta_u64(rows.iter().map(|r| r.c_custkey));
        w.strs(rows.iter().map(|r| r.c_name.as_str()));
        for r in rows {
            w.i32(r.c_nationkey);
        }
        for r in rows {
            w.i64(r.c_acctbal_cents);
        }
        w.0.extend(rows.iter().map(|r| r.c_mktsegment));
        w.strs(rows.iter().map(|r| r.c_comment.as_str()));
        RowGroup { n_rows: rows.len() as u32, bytes: w.0 }
    }

    fn decode_group(group: &RowGroup) -> Result<Vec<Self>, CodecError> {
        let n = group.n_rows as usize;
        let mut r = R { b: &group.bytes, pos: 0 };
        let keys = r.delta_u64(n)?;
        let names = r.strs(n)?;
        let nations: Vec<i32> = (0..n).map(|_| r.i32()).collect::<Result<_, _>>()?;
        let bals: Vec<i64> = (0..n).map(|_| r.i64()).collect::<Result<_, _>>()?;
        let segs = r.take(n)?.to_vec();
        let comments = r.strs(n)?;
        Ok((0..n)
            .map(|i| Customer {
                c_custkey: keys[i],
                c_name: names[i].clone(),
                c_nationkey: nations[i],
                c_acctbal_cents: bals[i],
                c_mktsegment: segs[i],
                c_comment: comments[i].clone(),
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tpch::{GenConfig, TpchGenerator};

    fn sample() -> (Vec<Order>, Vec<Lineitem>, Vec<Customer>) {
        let g = TpchGenerator::new(GenConfig { sf: 0.0005, ..Default::default() });
        (
            g.orders().into_iter().flatten().collect(),
            g.lineitems().into_iter().flatten().collect(),
            g.customers().into_iter().flatten().collect(),
        )
    }

    #[test]
    fn orders_roundtrip() {
        let (orders, _, _) = sample();
        let groups = Order::encode(&orders, 256);
        assert!(groups.len() > 1);
        assert_eq!(Order::decode(&groups).unwrap(), orders);
    }

    #[test]
    fn lineitems_roundtrip() {
        let (_, items, _) = sample();
        let groups = Lineitem::encode(&items, 500);
        assert_eq!(Lineitem::decode(&groups).unwrap(), items);
    }

    #[test]
    fn customers_roundtrip() {
        let (_, _, cust) = sample();
        let groups = Customer::encode(&cust, 64);
        assert_eq!(Customer::decode(&groups).unwrap(), cust);
    }

    #[test]
    fn delta_encoding_compresses_sorted_keys() {
        let (orders, _, _) = sample();
        let enc = Order::encode_group(&orders);
        // delta-varint orderkeys: ~1-2 bytes vs 8 raw
        let raw = orders.len() * 8;
        // total must be well under all-raw encoding of keys alone + rest
        assert!(enc.bytes.len() < raw * 8, "encoded {}", enc.bytes.len());
    }

    #[test]
    fn truncated_group_rejected() {
        let (orders, _, _) = sample();
        let mut g = Order::encode_group(&orders[..50]);
        g.bytes.truncate(g.bytes.len() / 2);
        assert!(Order::decode_group(&g).is_err());
    }

    #[test]
    fn empty_group_roundtrip() {
        let g = Order::encode_group(&[]);
        assert_eq!(Order::decode_group(&g).unwrap(), vec![]);
    }
}
