//! SBFCJ — the Spark Bloom-Filtered Cascade Join, modernised per the
//! paper's §5: the system contribution this repo reproduces.
//!
//! The five steps, each its own simulated stage with its own accounting:
//!
//! 1. **approximate count** of the small table (time-bounded);
//! 2. **optimal filter sizing** from (estimate, ε);
//! 3. **distributed filter build**: per-partition partial filters,
//!    OR-merged driver-wards (tree) — or, as the ablation baseline, the
//!    original driver-side build that collects all keys;
//! 4. **peer-to-peer broadcast** of the merged filter;
//! 5. **filter the big table** (fused with the scan) and **sort-merge
//!    join** the survivors through a 200-partition shuffle.
//!
//! The probe of step 5 can run through the native Rust filter or through
//! the AOT-compiled Pallas kernel (`runtime::XlaProbe`), selected by
//! [`ProbePath`] — both use the same hash algebra, pinned by golden
//! vectors, so results are identical.
//!
//! Execution is phased — **build** (steps 1–3), **broadcast** (step 4),
//! **probe** (step 5) — with a re-plan point between build and
//! broadcast: [`BloomCascadeJoin::execute_with_resize`] offers the
//! just-built filter's approximate count and ε to a [`ResizeDecision`]
//! hook, and rebuilds the filter at a corrected ε (the `bloom_resize`
//! stage) before anything is shipped.  That is the last moment the
//! filter's size is still a local decision; the adaptive planner
//! (`plan::adaptive`) uses it to fix a mis-sized ε mid-edge.

use std::sync::Arc;

use crate::approx::approx_count;
use crate::bloom::{BloomFilter, BloomParams, KeyFilter, SelectionVector};
use crate::cluster::faults::STRAGGLER_DELAY_S;
use crate::cluster::shuffle::{repartition, ShuffleCodec};
use crate::cluster::{broadcast, Cluster, Cost, FaultKind, FaultSession, Stage, Task};
use crate::dataset::PartitionedTable;
use crate::metrics::{QueryMetrics, StageTiming};
use crate::plan::costing::{retry_build_price, retry_ship_price, speculative_rerun_price};

use super::sort_merge::sort_merge_join_partition;
use super::{JoinedRow, Keyed, RowSize};

/// How step 3 builds the filter (ablation A1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FilterBuildStyle {
    /// Paper §5.1 change #1: partial filters per partition, tree-merged.
    Distributed,
    /// Brito et al. 2007 baseline: ship all keys to the driver, build
    /// there in one pass.
    DriverSide,
}

/// Which engine probes the filter during the big-table scan (ablation A4).
#[derive(Clone)]
pub enum ProbePath {
    /// Native Rust probe (`BloomFilter::contains_key`).
    Native,
    /// A batch-probe engine (the PJRT-loaded Pallas kernel).
    Batch(Arc<dyn BatchProbe>),
}

impl std::fmt::Debug for ProbePath {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProbePath::Native => write!(f, "Native"),
            ProbePath::Batch(_) => write!(f, "Batch(..)"),
        }
    }
}

/// Batched membership probe (implemented by `runtime::XlaProbe`).
pub trait BatchProbe: Send + Sync {
    /// One bool per key: false ⇒ definitely not in the filter.
    fn probe(&self, keys: &[u64], filter: &BloomFilter) -> Vec<bool>;
    fn name(&self) -> &'static str;
    /// Snap a raw (pre-rounding) filter-size request onto this engine's
    /// supported size ladder (AOT artifacts have static shapes —
    /// DESIGN.md §6).  `None` = no constraint / off-ladder.
    fn snap_m_bits(&self, _min_bits: f64) -> Option<u64> {
        None
    }
}

/// Mid-build re-sizing hook, called at the re-plan point between the
/// filter build and the broadcast with `(approximate build-side count,
/// the ε the filter was built at)`.  Returning `Some(new ε)` rebuilds
/// the filter at the new target before anything is shipped.
pub type ResizeDecision<'a> = &'a dyn Fn(u64, f64) -> Option<f64>;

/// What a mid-build re-size did (the adaptive ledger's raw material).
#[derive(Clone, Copy, Debug)]
pub struct FilterResize {
    pub old_fpr: f64,
    pub new_fpr: f64,
    /// Build-side approximate count the re-size was decided on.
    pub build_estimate: u64,
}

/// SBFCJ knobs.
#[derive(Clone, Debug)]
pub struct BloomCascadeConfig {
    /// Target false-positive rate ε — the paper's tunable.
    pub fpr: f64,
    pub build_style: FilterBuildStyle,
    pub probe_path: ProbePath,
    /// Simulated budget for the approximate count (step 1), seconds.
    pub count_budget_s: f64,
    /// Shuffle serialisation (Tungsten vs JavaSer — ablation A3 input).
    pub codec: ShuffleCodec,
}

impl Default for BloomCascadeConfig {
    fn default() -> Self {
        BloomCascadeConfig {
            fpr: 0.05,
            build_style: FilterBuildStyle::Distributed,
            probe_path: ProbePath::Native,
            count_budget_s: 2.0,
            codec: ShuffleCodec::Tungsten,
        }
    }
}

/// The coordinator.
pub struct BloomCascadeJoin {
    pub cfg: BloomCascadeConfig,
}

impl BloomCascadeJoin {
    pub fn new(cfg: BloomCascadeConfig) -> Self {
        BloomCascadeJoin { cfg }
    }

    /// Execute the cascade join on `cluster`.  Inputs are keyed,
    /// partitioned tables (WHERE-clauses already applied by the caller's
    /// fused scan pipeline — see `query.rs`).
    pub fn execute<B, S>(
        &self,
        cluster: &Cluster,
        big: PartitionedTable<Keyed<B>>,
        small: PartitionedTable<Keyed<S>>,
    ) -> (Vec<JoinedRow<B, S>>, QueryMetrics)
    where
        B: Clone + Send + Sync + RowSize + 'static,
        S: Clone + Send + Sync + RowSize + 'static,
    {
        let (rows, metrics, _) = self.execute_with_resize(cluster, big, small, None);
        (rows, metrics)
    }

    /// [`execute`] with the mid-build re-plan point armed: after the
    /// filter build and before the broadcast, `resize` may replace the
    /// filter's ε, paying a second build stage (`bloom_resize`) to avoid
    /// shipping and probing with a mis-sized filter.
    ///
    /// [`execute`]: BloomCascadeJoin::execute
    pub fn execute_with_resize<B, S>(
        &self,
        cluster: &Cluster,
        big: PartitionedTable<Keyed<B>>,
        small: PartitionedTable<Keyed<S>>,
        resize: Option<ResizeDecision<'_>>,
    ) -> (Vec<JoinedRow<B, S>>, QueryMetrics, Option<FilterResize>)
    where
        B: Clone + Send + Sync + RowSize + 'static,
        S: Clone + Send + Sync + RowSize + 'static,
    {
        let (rows, metrics, resized, _) =
            self.execute_phased(cluster, big, small, resize, None, None);
        (rows, metrics, resized)
    }

    /// [`execute_with_resize`] that also hands back the broadcast filter,
    /// so a long-running service can publish it to its cross-query filter
    /// cache.
    ///
    /// [`execute_with_resize`]: BloomCascadeJoin::execute_with_resize
    pub fn execute_returning_filter<B, S>(
        &self,
        cluster: &Cluster,
        big: PartitionedTable<Keyed<B>>,
        small: PartitionedTable<Keyed<S>>,
        resize: Option<ResizeDecision<'_>>,
    ) -> (Vec<JoinedRow<B, S>>, QueryMetrics, Option<FilterResize>, Arc<BloomFilter>)
    where
        B: Clone + Send + Sync + RowSize + 'static,
        S: Clone + Send + Sync + RowSize + 'static,
    {
        self.execute_phased(cluster, big, small, resize, None, None)
    }

    /// Run the cascade with a filter already built by an earlier query
    /// over the same build side (same relation, predicate, ε and data
    /// version — the server's filter-cache key guarantees it).  Steps 1–3
    /// and the re-size point are skipped: the query pays only broadcast +
    /// stage 2, and a zero-cost `filter_cached` marker stage records the
    /// hit in the metrics ledger (deliberately outside both §7 stage
    /// buckets, so ledger stage sums still reconcile).
    pub fn execute_with_prebuilt<B, S>(
        &self,
        cluster: &Cluster,
        big: PartitionedTable<Keyed<B>>,
        small: PartitionedTable<Keyed<S>>,
        filter: Arc<BloomFilter>,
    ) -> (Vec<JoinedRow<B, S>>, QueryMetrics)
    where
        B: Clone + Send + Sync + RowSize + 'static,
        S: Clone + Send + Sync + RowSize + 'static,
    {
        let (rows, metrics, _, _) =
            self.execute_phased(cluster, big, small, None, Some(filter), None);
        (rows, metrics)
    }

    /// The fully general entry point: [`execute_returning_filter`] plus an
    /// optional prebuilt filter (the cache-hit path) and an optional
    /// fault-injection session (`cluster::faults`).  With an active
    /// session the cascade injects and recovers from broadcast drops
    /// (`retry_ship`), worker panics in the filtered scan (`retry_build`)
    /// and stragglers (`speculative_rerun`); the recovered result is
    /// always bit-identical to the fault-free run, only the booked
    /// recovery stages differ.  `faults: None` is byte-for-byte the old
    /// behaviour.
    ///
    /// [`execute_returning_filter`]: BloomCascadeJoin::execute_returning_filter
    pub fn execute_faulted<B, S>(
        &self,
        cluster: &Cluster,
        big: PartitionedTable<Keyed<B>>,
        small: PartitionedTable<Keyed<S>>,
        resize: Option<ResizeDecision<'_>>,
        prebuilt: Option<Arc<BloomFilter>>,
        faults: Option<&FaultSession>,
    ) -> (Vec<JoinedRow<B, S>>, QueryMetrics, Option<FilterResize>, Arc<BloomFilter>)
    where
        B: Clone + Send + Sync + RowSize + 'static,
        S: Clone + Send + Sync + RowSize + 'static,
    {
        self.execute_phased(cluster, big, small, resize, prebuilt, faults)
    }

    /// Steps 1–4 of the cascade — approximate count, optimal sizing (with
    /// the XLA artifact-ladder snap when a batch engine is configured),
    /// distributed/driver-side build, the mid-build re-size point, and the
    /// p2p broadcast with `BroadcastDrop` recovery — booked into `metrics`,
    /// **without** the probe/shuffle/join tail.  This is the build-only
    /// entry the fused probe pipeline uses to materialise each group
    /// filter before its single pass over the fact stream; `execute_*`
    /// runs through exactly this code, so a fused build is stage-for-stage
    /// identical to an edge-at-a-time one.  `prebuilt` is the cache-hit
    /// path (zero-cost `filter_cached` marker, straight to broadcast).
    pub fn build_filter_faulted<S>(
        &self,
        cluster: &Cluster,
        small: &PartitionedTable<Keyed<S>>,
        resize: Option<ResizeDecision<'_>>,
        prebuilt: Option<Arc<BloomFilter>>,
        faults: Option<&FaultSession>,
        metrics: &mut QueryMetrics,
    ) -> (Arc<BloomFilter>, Option<FilterResize>)
    where
        S: Clone + Send + Sync + 'static,
    {
        let cfg = cluster.config().clone();
        metrics.requested_fpr = self.cfg.fpr;

        let mut resized: Option<FilterResize> = None;
        let filter: Arc<BloomFilter> = if let Some(cached) = prebuilt {
            // cache hit: the build side is already summarised — record the
            // reused filter's shape and jump straight to the broadcast
            let params = cached.params();
            metrics.bloom_bits = params.m_bits;
            metrics.realized_fpr = params.realized_fpr(small.n_rows() as u64);
            metrics.push(StageTiming::new(
                "filter_cached",
                crate::cluster::SimDuration::ZERO,
            ));
            cached
        } else {
            // -- step 1: approximate count --------------------------------
            let sizes: Vec<usize> = small.partitions().iter().map(Vec::len).collect();
            let est = approx_count(&cfg, &sizes, self.cfg.count_budget_s, 2e-8);
            metrics.push(StageTiming {
                tasks: est.partitions_seen,
                ..StageTiming::new(
                    "approx_count",
                    crate::cluster::SimDuration::from_secs(est.sim_s),
                )
            });

            // -- step 2: sizing ---------------------------------------------
            let sized = |fpr: f64| {
                let mut params = BloomParams::optimal(est.estimate.max(1), fpr);
                // with an XLA probe engine, snap the size up to its artifact
                // ladder so the AOT kernel (static shapes) can run the scan
                if let ProbePath::Batch(engine) = &self.cfg.probe_path {
                    let raw = crate::model::CostModel::filter_bits(est.estimate.max(1), fpr);
                    if let Some(m) = engine.snap_m_bits(raw) {
                        params = BloomParams::with_m(est.estimate.max(1), fpr, m);
                    }
                }
                params
            };
            let mut params = sized(self.cfg.fpr);
            metrics.bloom_bits = params.m_bits;

            // -- step 3: build ------------------------------------------------
            let build = |params: BloomParams| match self.cfg.build_style {
                FilterBuildStyle::Distributed => self.build_distributed(cluster, small, params),
                FilterBuildStyle::DriverSide => self.build_driver_side(cluster, small, params),
            };
            let (mut filter, build_timing) = build(params);
            metrics.realized_fpr = params.realized_fpr(small.n_rows() as u64);
            metrics.push(build_timing);

            // -- re-plan point: re-size before broadcast ----------------------
            // the filter exists but nothing has shipped; a corrected ε can
            // still replace it for the price of a second build stage
            if let Some(decide) = resize {
                if let Some(new_fpr) = decide(est.estimate.max(1), self.cfg.fpr) {
                    params = sized(new_fpr);
                    let (rebuilt, mut timing) = build(params);
                    timing.name = "bloom_resize".to_string();
                    filter = rebuilt;
                    metrics.bloom_bits = params.m_bits;
                    metrics.requested_fpr = new_fpr;
                    metrics.realized_fpr = params.realized_fpr(small.n_rows() as u64);
                    metrics.push(timing);
                    let old_fpr = self.cfg.fpr;
                    resized =
                        Some(FilterResize { old_fpr, new_fpr, build_estimate: est.estimate });
                }
            }
            Arc::new(filter)
        };

        // -- step 4: broadcast ---------------------------------------------
        let filter_bytes = filter.to_bytes().len() as u64;
        let bc = broadcast::p2p_broadcast_cost(&cfg, filter_bytes);
        metrics.push(
            StageTiming::new("broadcast", bc).with_cost(&Cost {
                net_bytes: filter_bytes * cfg.total_executors() as u64,
                ..Default::default()
            }),
        );
        // injected fault: the ship is dropped before every executor has
        // the filter — back off (simulated) and re-ship, paying the full
        // duplicate broadcast traffic under the typed `retry_ship` stage
        if let Some(fs) = faults {
            let mut attempt = 0u32;
            while fs.should_fire(FaultKind::BroadcastDrop, "broadcast") {
                attempt += 1;
                let backoff = fs.backoff(attempt);
                let (sim, cost) = retry_ship_price(&cfg, filter_bytes, backoff.seconds());
                metrics.push(StageTiming::new("retry_ship", sim).with_cost(&cost));
                fs.log_recovery(
                    "retry_ship",
                    "broadcast",
                    format!(
                        "broadcast of {filter_bytes} B dropped; re-shipped after {:.3}s backoff",
                        backoff.seconds()
                    ),
                    sim.seconds(),
                );
            }
        }
        (filter, resized)
    }

    fn execute_phased<B, S>(
        &self,
        cluster: &Cluster,
        big: PartitionedTable<Keyed<B>>,
        small: PartitionedTable<Keyed<S>>,
        resize: Option<ResizeDecision<'_>>,
        prebuilt: Option<Arc<BloomFilter>>,
        faults: Option<&FaultSession>,
    ) -> (Vec<JoinedRow<B, S>>, QueryMetrics, Option<FilterResize>, Arc<BloomFilter>)
    where
        B: Clone + Send + Sync + RowSize + 'static,
        S: Clone + Send + Sync + RowSize + 'static,
    {
        let cfg = cluster.config().clone();
        let mut metrics = QueryMetrics::default();
        metrics.big_rows_scanned = big.n_rows() as u64;

        let (filter, resized) =
            self.build_filter_faulted(cluster, &small, resize, prebuilt, faults, &mut metrics);

        // -- step 5a: filtered scan ------------------------------------------
        let probe = self.cfg.probe_path.clone();
        let n_nodes = cfg.n_nodes;
        let parts = big.into_partitions();
        let part_lens: Vec<usize> = parts.iter().map(Vec::len).collect();
        let n_parts = parts.len().max(1);
        // fault decisions happen here on the coordinator, before any task
        // is submitted, so firing is thread-count invariant
        let panic_victim = faults.and_then(|fs| {
            fs.should_fire(FaultKind::WorkerPanic, "filter_scan")
                .then(|| fs.target_index(n_parts))
        });
        let straggler_victim = faults.and_then(|fs| {
            fs.should_fire(FaultKind::Straggler, "filter_scan").then(|| fs.target_index(n_parts))
        });
        let make_tasks = |parts: Vec<Vec<Keyed<B>>>,
                          victim: Option<usize>|
         -> Vec<Task<Vec<Keyed<B>>>> {
            parts
                .into_iter()
                .enumerate()
                .map(|(p, part)| {
                    let filter = Arc::clone(&filter);
                    let probe = probe.clone();
                    let disk_bytes: u64 = part.iter().map(|(_, b)| 8 + b.row_bytes()).sum();
                    let disk_s = disk_bytes as f64 / cfg.disk_bandwidth;
                    // modeled JVM-scale scan cost (see ClusterConfig docs):
                    // keeps simulated time faithful to the paper's platform
                    // and identical across probe engines
                    let cpu_s = part.len() as f64 * cfg.scan_record_cost;
                    Task::new(move || {
                        if victim == Some(p) {
                            panic!("injected worker panic in filter_scan partition {p}");
                        }
                        let survivors = match &probe {
                            // vectorized native path: hash a chunk of keys up
                            // front, keep survivors as a selection vector,
                            // materialise only the surviving rows
                            ProbePath::Native => {
                                let keys: Vec<u64> = part.iter().map(|(k, _)| *k).collect();
                                let mut sel = SelectionVector::with_capacity(keys.len());
                                filter.probe_batch(&keys, &mut sel);
                                sel.gather_owned(part)
                            }
                            ProbePath::Batch(engine) => {
                                let keys: Vec<u64> = part.iter().map(|(k, _)| *k).collect();
                                let mask = engine.probe(&keys, &filter);
                                part.into_iter()
                                    .zip(mask)
                                    .filter_map(|(row, keep)| keep.then_some(row))
                                    .collect()
                            }
                        };
                        (survivors, Cost { cpu_s, disk_s, disk_bytes, ..Default::default() })
                    })
                    .with_locality(p % n_nodes)
                })
                .collect()
        };
        // injected fault: a real panic on the real pool in the seed-picked
        // partition.  The failed attempt's outputs are discarded and only
        // the typed `retry_build` recovery stage is booked, so the
        // measured filter_scan split stays fault-free.
        if let Some(v) = panic_victim {
            let fs = faults.expect("victim implies an active session");
            let failed = cluster
                .try_run_stage(Stage::new("filter_scan", make_tasks(parts.clone(), Some(v))))
                .map(|_| ())
                .expect_err("injected panic must fail the stage");
            let backoff = fs.backoff(1);
            let sim =
                retry_build_price(&cfg, part_lens[v] as f64 * cfg.scan_record_cost, backoff.seconds());
            metrics.push(StageTiming { tasks: 1, ..StageTiming::new("retry_build", sim) });
            fs.log_recovery(
                "retry_build",
                "filter_scan",
                format!("{failed}; stage retried without the fault"),
                sim.seconds(),
            );
        }
        let scan = cluster.run_stage(Stage::new("filter_scan", make_tasks(parts, None)));
        let filtered: Vec<Vec<Keyed<B>>> = scan.outputs;
        metrics.big_rows_after_filter = filtered.iter().map(|p| p.len() as u64).sum();
        metrics.push(StageTiming {
            tasks: scan.n_tasks,
            wall_s: scan.wall_time.seconds(),
            cpu_s: scan.total_cost.cpu_s,
            net_bytes: scan.total_cost.net_bytes,
            disk_bytes: scan.total_cost.disk_bytes,
            ..StageTiming::new("filter_scan", scan.sim_time)
        });
        // injected fault: the seed-picked scan task straggles; a
        // speculative copy elsewhere overtakes it, so the main stage keeps
        // its fault-free timing and only the copy's price is booked
        if let Some(v) = straggler_victim {
            let fs = faults.expect("victim implies an active session");
            let sim = speculative_rerun_price(&cfg, part_lens[v] as f64 * cfg.scan_record_cost);
            metrics.push(StageTiming { tasks: 1, ..StageTiming::new("speculative_rerun", sim) });
            fs.log_recovery(
                "speculative_rerun",
                "filter_scan",
                format!("partition {v} straggled {STRAGGLER_DELAY_S}s; speculative copy won"),
                sim.seconds(),
            );
        }

        // -- step 5b: shuffle both sides -------------------------------------
        let n_shuffle = cfg.shuffle_partitions;
        let (big_buckets, big_vol) =
            repartition(filtered, n_shuffle, |b: &B| b.row_bytes());
        let (small_buckets, small_vol) =
            repartition(small.into_partitions(), n_shuffle, |s: &S| s.row_bytes());
        let mut ex_cost = big_vol.exchange_cost(&cfg, self.cfg.codec);
        ex_cost.merge(&small_vol.exchange_cost(&cfg, self.cfg.codec));
        metrics.push(
            StageTiming {
                tasks: n_shuffle,
                ..StageTiming::new(
                    "shuffle",
                    crate::cluster::SimDuration::from_secs(ex_cost.total_seconds(cfg.cpu_scale)),
                )
            }
            .with_cost(&ex_cost),
        );

        // -- step 5c: per-partition sort-merge join ---------------------------
        let tasks: Vec<Task<Vec<JoinedRow<B, S>>>> = big_buckets
            .into_iter()
            .zip(small_buckets)
            .map(|(b, s)| {
                let disk_bw = cfg.disk_bandwidth;
                let sort_c = cfg.sort_compare_cost;
                let merge_c = cfg.merge_record_cost;
                Task::new(move || {
                    // modeled JVM sort+merge cost (the paper's §7.1.2
                    // TimSort / Poly·log Poly term)
                    let nlogn = |n: usize| {
                        if n < 2 { n as f64 } else { n as f64 * (n as f64).log2() }
                    };
                    let cpu_s = sort_c * (nlogn(b.len()) + nlogn(s.len()))
                        + merge_c * (b.len() + s.len()) as f64;
                    let out = sort_merge_join_partition(b, s);
                    let cpu_s = cpu_s + merge_c * out.len() as f64;
                    let write_bytes: u64 =
                        out.iter().map(|(_, b, s)| 8 + b.row_bytes() + s.row_bytes()).sum();
                    let disk_s = write_bytes as f64 / disk_bw;
                    (out, Cost { cpu_s, disk_s, disk_bytes: write_bytes, ..Default::default() })
                })
            })
            .collect();
        let join = cluster.run_stage(Stage::new("join", tasks));
        let rows: Vec<JoinedRow<B, S>> = join.outputs.into_iter().flatten().collect();
        metrics.push(StageTiming {
            tasks: join.n_tasks,
            wall_s: join.wall_time.seconds(),
            cpu_s: join.total_cost.cpu_s,
            disk_bytes: join.total_cost.disk_bytes,
            ..StageTiming::new("join", join.sim_time)
        });

        metrics.output_rows = rows.len() as u64;
        (rows, metrics, resized, filter)
    }

    /// §5.1 change #1: per-partition partial build + tree OR-merge.
    fn build_distributed<S>(
        &self,
        cluster: &Cluster,
        small: &PartitionedTable<Keyed<S>>,
        params: BloomParams,
    ) -> (BloomFilter, StageTiming)
    where
        S: Clone + Send + Sync + 'static,
    {
        let cfg = cluster.config();
        let tasks: Vec<Task<BloomFilter>> = small
            .partitions()
            .iter()
            .map(|part| {
                let keys: Vec<u64> = part.iter().map(|(k, _)| *k).collect();
                let hash_c = cfg.hash_insert_cost;
                let scan_c = cfg.scan_record_cost;
                Task::new(move || {
                    // modeled cost: read the partition + k hash
                    // applications per key (the paper's per-bit K1 term
                    // shows up in the merge/broadcast legs below)
                    let cpu_s = keys.len() as f64 * (scan_c + hash_c * params.k as f64);
                    let mut f = BloomFilter::new(params);
                    for k in keys {
                        f.insert(k);
                    }
                    (f, Cost { cpu_s, ..Default::default() })
                })
            })
            .collect();
        let stage = cluster.run_stage(Stage::new("bloom_build", tasks));

        // tree-merge the partials (driver side; cost = collect of filter
        // bytes + the measured OR time)
        let t0 = std::time::Instant::now();
        let mut it = stage.outputs.into_iter();
        let mut merged = it.next().unwrap_or_else(|| BloomFilter::new(params));
        for partial in it {
            merged.merge(&partial).expect("identical params by construction");
        }
        let merge_cpu = t0.elapsed().as_secs_f64();
        let collect = broadcast::driver_collect_cost(cfg, params.size_bytes());

        let sim = stage.sim_time
            + collect
            + crate::cluster::SimDuration::from_secs(merge_cpu * cfg.cpu_scale);
        let timing = StageTiming {
            tasks: stage.n_tasks,
            wall_s: stage.wall_time.seconds() + merge_cpu,
            cpu_s: stage.total_cost.cpu_s + merge_cpu,
            net_bytes: params.size_bytes() * stage.n_tasks as u64,
            ..StageTiming::new("bloom_build", sim)
        };
        (merged, timing)
    }

    /// Brito et al. baseline: collect every key at the driver, build once.
    fn build_driver_side<S>(
        &self,
        cluster: &Cluster,
        small: &PartitionedTable<Keyed<S>>,
        params: BloomParams,
    ) -> (BloomFilter, StageTiming)
    where
        S: Clone,
    {
        let cfg = cluster.config();
        let key_bytes: u64 = 8 * small.n_rows() as u64 / cfg.total_executors().max(1) as u64;
        let collect = broadcast::flat_collect_cost(cfg, key_bytes);
        let mut f = BloomFilter::new(params);
        for (k, _) in small.iter() {
            f.insert(*k);
        }
        // modeled serial driver build (no slot parallelism — the point of
        // the ablation)
        let cpu = small.n_rows() as f64
            * (cfg.scan_record_cost + cfg.hash_insert_cost * params.k as f64);
        let sim = collect + crate::cluster::SimDuration::from_secs(cpu * cfg.cpu_scale);
        let timing = StageTiming {
            tasks: 1,
            wall_s: cpu,
            cpu_s: cpu,
            net_bytes: 8 * small.n_rows() as u64,
            ..StageTiming::new("bloom_build", sim)
        };
        (f, timing)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;
    use crate::util::Rng;

    fn inputs(
        n_big: usize,
        n_small: usize,
        key_space: u64,
    ) -> (PartitionedTable<Keyed<u64>>, PartitionedTable<Keyed<u64>>) {
        let mut rng = Rng::new(42);
        let big: Vec<Keyed<u64>> =
            (0..n_big).map(|_| (rng.below(key_space), rng.next_u64())).collect();
        let small: Vec<Keyed<u64>> =
            (0..n_small).map(|_| (rng.below(key_space / 10), rng.next_u64())).collect();
        (
            PartitionedTable::from_rows(big, 4),
            PartitionedTable::from_rows(small, 2),
        )
    }

    fn oracle_count(
        big: &PartitionedTable<Keyed<u64>>,
        small: &PartitionedTable<Keyed<u64>>,
    ) -> usize {
        use std::collections::HashMap;
        let mut sc: HashMap<u64, usize> = HashMap::new();
        for (k, _) in small.iter() {
            *sc.entry(*k).or_default() += 1;
        }
        big.iter().map(|(k, _)| sc.get(k).copied().unwrap_or(0)).sum()
    }

    #[test]
    fn produces_exact_join_result() {
        let cluster = Cluster::new(ClusterConfig::local());
        let (big, small) = inputs(2_000, 200, 10_000);
        let want = oracle_count(&big, &small);
        let join = BloomCascadeJoin::new(BloomCascadeConfig::default());
        let (rows, metrics) = join.execute(&cluster, big, small);
        assert_eq!(rows.len(), want);
        assert_eq!(metrics.output_rows as usize, want);
    }

    #[test]
    fn filter_actually_filters() {
        let cluster = Cluster::new(ClusterConfig::local());
        let (big, small) = inputs(5_000, 100, 100_000);
        let join = BloomCascadeJoin::new(BloomCascadeConfig { fpr: 0.01, ..Default::default() });
        let scanned = big.n_rows() as u64;
        let (_, metrics) = join.execute(&cluster, big, small);
        assert_eq!(metrics.big_rows_scanned, scanned);
        // key space 100k, small keys < 10k: most big rows filterable
        assert!(
            metrics.big_rows_after_filter < scanned / 2,
            "{} of {scanned} survived",
            metrics.big_rows_after_filter
        );
    }

    #[test]
    fn driver_side_build_same_result() {
        let cluster = Cluster::new(ClusterConfig::local());
        let (big, small) = inputs(1_000, 150, 5_000);
        let want = oracle_count(&big, &small);
        let join = BloomCascadeJoin::new(BloomCascadeConfig {
            build_style: FilterBuildStyle::DriverSide,
            ..Default::default()
        });
        let (rows, _) = join.execute(&cluster, big, small);
        assert_eq!(rows.len(), want);
    }

    #[test]
    fn lower_fpr_means_bigger_filter_and_fewer_survivors() {
        let cluster = Cluster::new(ClusterConfig::local());
        let (big, small) = inputs(20_000, 100, 1_000_000);
        let loose = BloomCascadeJoin::new(BloomCascadeConfig { fpr: 0.5, ..Default::default() });
        let tight = BloomCascadeJoin::new(BloomCascadeConfig { fpr: 0.001, ..Default::default() });
        let (_, m_loose) = loose.execute(&cluster, big.clone(), small.clone());
        let (_, m_tight) = tight.execute(&cluster, big, small);
        assert!(m_tight.bloom_bits > m_loose.bloom_bits);
        assert!(m_tight.big_rows_after_filter <= m_loose.big_rows_after_filter);
    }

    #[test]
    fn resize_hook_rebuilds_before_broadcast() {
        let cluster = Cluster::new(ClusterConfig::local());
        let join = BloomCascadeJoin::new(BloomCascadeConfig { fpr: 0.5, ..Default::default() });

        // a declining hook leaves the planned filter in place
        let (big, small) = inputs(5_000, 100, 100_000);
        let want = oracle_count(&big, &small);
        let none = |_: u64, _: f64| -> Option<f64> { None };
        let (rows, loose, resized) = join.execute_with_resize(&cluster, big, small, Some(&none));
        assert_eq!(rows.len(), want);
        assert!(resized.is_none() && loose.stage("bloom_resize").is_none());

        // a correcting hook rebuilds tighter before anything ships
        let (big, small) = inputs(5_000, 100, 100_000);
        let decide = |n: u64, old: f64| {
            assert!(n > 0 && (old - 0.5).abs() < 1e-12);
            Some(0.001)
        };
        let (rows, tight, resized) =
            join.execute_with_resize(&cluster, big, small, Some(&decide));
        assert_eq!(rows.len(), want, "re-sizing must not change the result");
        let r = resized.expect("hook returned a new ε");
        assert!((r.old_fpr - 0.5).abs() < 1e-12 && (r.new_fpr - 0.001).abs() < 1e-12);
        assert!(r.build_estimate > 0);
        assert!(tight.stage("bloom_resize").is_some());
        assert!((tight.requested_fpr - 0.001).abs() < 1e-12);
        // the rebuilt filter is the one that probed: bigger, and stricter
        assert!(tight.bloom_bits > loose.bloom_bits);
        assert!(tight.big_rows_after_filter <= loose.big_rows_after_filter);
        // the rebuild is priced as build-side (stage 1) work
        assert!(tight.bloom_creation_s() > loose.bloom_creation_s());
    }

    #[test]
    fn prebuilt_filter_skips_build_and_matches_cold_run() {
        let cluster = Cluster::new(ClusterConfig::local());
        let join = BloomCascadeJoin::new(BloomCascadeConfig { fpr: 0.01, ..Default::default() });

        let (big, small) = inputs(5_000, 100, 100_000);
        let (cold_rows, cold_m, resized, filter) =
            join.execute_returning_filter(&cluster, big, small, None);
        assert!(resized.is_none());
        assert!(cold_m.stage("bloom_build").is_some());

        // same inputs, filter served from the "cache": identical output
        let (big, small) = inputs(5_000, 100, 100_000);
        let (warm_rows, warm_m) = join.execute_with_prebuilt(&cluster, big, small, filter);
        assert_eq!(warm_rows, cold_rows, "cache hit must be bit-identical");
        assert_eq!(warm_m.output_rows, cold_m.output_rows);
        assert_eq!(warm_m.bloom_bits, cold_m.bloom_bits);
        assert_eq!(warm_m.big_rows_after_filter, cold_m.big_rows_after_filter);

        // the hit pays no build-side stages — only the marker + broadcast
        for skipped in ["approx_count", "bloom_build", "bloom_resize"] {
            assert!(warm_m.stage(skipped).is_none(), "{skipped} must be skipped on a hit");
        }
        let marker = warm_m.stage("filter_cached").expect("hit marker stage");
        assert_eq!(marker.sim_s, 0.0);
        assert!(warm_m.stage("broadcast").is_some(), "the reused filter still ships");
        assert!(warm_m.bloom_creation_s() < cold_m.bloom_creation_s());
    }

    #[test]
    fn injected_faults_recover_bit_identical() {
        use crate::cluster::{FaultPlan, FaultSession};
        let cluster = Cluster::new(ClusterConfig::local());
        let join = BloomCascadeJoin::new(BloomCascadeConfig { fpr: 0.05, ..Default::default() });
        let (big, small) = inputs(2_000, 200, 10_000);
        let (clean_rows, clean_m) = join.execute(&cluster, big.clone(), small.clone());
        assert_eq!(clean_m.recovery_s(), 0.0, "fault-free runs book zero recovery");

        // chaos fires the cascade's three applicable kinds: broadcast
        // drop, worker panic in the scan, straggler
        let fs = FaultSession::new(FaultPlan::parse("chaos").unwrap());
        let (rows, m, _, _) = join.execute_faulted(&cluster, big, small, None, None, Some(&fs));
        assert_eq!(rows, clean_rows, "recovered result must be bit-identical");
        for stage in ["retry_ship", "retry_build", "speculative_rerun"] {
            assert!(m.stage(stage).is_some(), "missing recovery stage {stage}");
        }
        assert!(m.recovery_s() > 0.0);
        assert_eq!(fs.injected().len(), 3);
        assert_eq!(fs.recovered().len(), 3);
        // shipped-byte conservation: the faulted run pays exactly one
        // duplicate broadcast on top of the clean traffic
        let dup = m.stage("retry_ship").unwrap().net_bytes;
        assert_eq!(dup, clean_m.stage("broadcast").unwrap().net_bytes);
        assert_eq!(m.total_net_bytes(), clean_m.total_net_bytes() + dup);
    }

    #[test]
    fn metrics_have_all_five_stages() {
        let cluster = Cluster::new(ClusterConfig::local());
        let (big, small) = inputs(500, 50, 1_000);
        let join = BloomCascadeJoin::new(BloomCascadeConfig::default());
        let (_, metrics) = join.execute(&cluster, big, small);
        for stage in ["approx_count", "bloom_build", "broadcast", "filter_scan", "shuffle", "join"] {
            assert!(metrics.stage(stage).is_some(), "missing {stage}");
        }
        assert!(metrics.bloom_creation_s() > 0.0);
        assert!(metrics.filter_join_s() > 0.0);
    }
}
