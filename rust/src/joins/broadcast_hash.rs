//! Broadcast hash join — "SBJ" in Brito et al., Spark's
//! `BroadcastHashJoin`: ship the whole (filtered) small table to every
//! executor, build a hash map once per executor, stream the big table
//! through it.  No shuffle of the big side at all — unbeatable when the
//! small side fits in executor memory, which is exactly the regime the
//! paper contrasts SBFCJ against.

use std::collections::HashMap;

use super::{JoinedRow, Keyed, RowSize};

/// Build the broadcast hash table.
pub fn build_hash_table<S: Clone>(small: &[Keyed<S>]) -> HashMap<u64, Vec<S>> {
    let mut map: HashMap<u64, Vec<S>> = HashMap::with_capacity(small.len());
    for (k, s) in small {
        map.entry(*k).or_default().push(s.clone());
    }
    map
}

/// Probe one big-table partition against the broadcast table.
pub fn probe_partition<B: Clone, S: Clone>(
    big: &[Keyed<B>],
    table: &HashMap<u64, Vec<S>>,
) -> Vec<JoinedRow<B, S>> {
    let mut out = Vec::new();
    for (k, b) in big {
        if let Some(matches) = table.get(k) {
            for s in matches {
                out.push((*k, b.clone(), s.clone()));
            }
        }
    }
    out
}

/// Serialized size of the broadcast payload (what the torrent ships).
pub fn broadcast_bytes<S: RowSize>(small: &[Keyed<S>]) -> u64 {
    small.iter().map(|(_, s)| 8 + s.row_bytes()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::joins::sort_merge::sort_merge_join_partition;
    use crate::util::Rng;

    #[test]
    fn agrees_with_sort_merge() {
        let mut rng = Rng::new(7);
        let big: Vec<Keyed<u32>> =
            (0..300).map(|_| (rng.below(40), rng.next_u32())).collect();
        let small: Vec<Keyed<u32>> =
            (0..50).map(|_| (rng.below(40), rng.next_u32())).collect();
        let table = build_hash_table(&small);
        let mut got = probe_partition(&big, &table);
        let mut want = sort_merge_join_partition(big, small);
        got.sort();
        want.sort();
        assert_eq!(got, want);
    }

    #[test]
    fn broadcast_bytes_counts_keys_and_payloads() {
        let small: Vec<Keyed<u64>> = vec![(1, 10), (2, 20)];
        assert_eq!(broadcast_bytes(&small), 2 * (8 + 8));
    }

    #[test]
    fn empty_table_probes_empty() {
        let table = build_hash_table::<u32>(&[]);
        let big: Vec<Keyed<u32>> = vec![(1, 2), (3, 4)];
        assert!(probe_partition(&big, &table).is_empty());
    }
}
