//! TimSort (Peters 2002) — from scratch.
//!
//! The paper's §7.1.2 attributes part of the ε-linear join cost to
//! "re-sorting elements with the TimSort algorithm" (what the JVM sorts
//! shuffle runs with), so the sort in our sort-merge join is the real
//! thing: natural-run detection with strictly-descending-run reversal,
//! binary-insertion extension of short runs to `minrun`, a run stack with
//! the classic (A > B+C, B > C) invariants, and galloping merges.

const MIN_MERGE: usize = 32;
const MIN_GALLOP: usize = 7;

/// Sort `v` by `key` (stable).
pub fn timsort_by_key<T, K: Ord>(v: &mut [T], key: impl Fn(&T) -> K) {
    timsort_by(v, |a, b| key(a).cmp(&key(b)));
}

/// Stable sort with an explicit comparator.
pub fn timsort_by<T>(v: &mut [T], mut cmp: impl FnMut(&T, &T) -> std::cmp::Ordering) {
    let n = v.len();
    if n < 2 {
        return;
    }
    if n < MIN_MERGE {
        let run_end = count_run(v, &mut cmp);
        binary_insertion(v, run_end, &mut cmp);
        return;
    }

    let minrun = min_run_length(n);
    // run stack: (start, len)
    let mut runs: Vec<(usize, usize)> = Vec::new();
    let mut pos = 0;
    while pos < n {
        let mut run_len = count_run(&mut v[pos..], &mut cmp);
        if run_len < minrun {
            let force = minrun.min(n - pos);
            binary_insertion(&mut v[pos..pos + force], run_len, &mut cmp);
            run_len = force;
        }
        runs.push((pos, run_len));
        pos += run_len;
        collapse(v, &mut runs, &mut cmp);
    }
    // final collapse
    while runs.len() > 1 {
        let r = runs.len();
        merge_at(v, &mut runs, r - 2, &mut cmp);
    }
    debug_assert_eq!(runs[0], (0, n));
}

/// Length of the run starting at v[0]; strictly-descending runs reversed.
fn count_run<T>(v: &mut [T], cmp: &mut impl FnMut(&T, &T) -> std::cmp::Ordering) -> usize {
    let n = v.len();
    if n <= 1 {
        return n;
    }
    let mut i = 1;
    if cmp(&v[1], &v[0]).is_lt() {
        // strictly descending (strictness keeps stability)
        while i + 1 < n && cmp(&v[i + 1], &v[i]).is_lt() {
            i += 1;
        }
        v[..=i].reverse();
    } else {
        while i + 1 < n && !cmp(&v[i + 1], &v[i]).is_lt() {
            i += 1;
        }
    }
    i + 1
}

/// Extend a sorted prefix of length `sorted` to cover all of `v`.
fn binary_insertion<T>(
    v: &mut [T],
    sorted: usize,
    cmp: &mut impl FnMut(&T, &T) -> std::cmp::Ordering,
) {
    for i in sorted.max(1)..v.len() {
        // binary search for insertion point of v[i] in v[..i] (stable:
        // insert after equals)
        let mut lo = 0;
        let mut hi = i;
        while lo < hi {
            let mid = (lo + hi) / 2;
            if cmp(&v[i], &v[mid]).is_lt() {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        v[lo..=i].rotate_right(1);
    }
}

/// CPython's minrun: n/2^k in [16, 32], rounding up if any bits shifted out.
fn min_run_length(mut n: usize) -> usize {
    let mut r = 0;
    while n >= MIN_MERGE {
        r |= n & 1;
        n >>= 1;
    }
    n + r
}

/// Restore the stack invariants by merging.
fn collapse<T>(
    v: &mut [T],
    runs: &mut Vec<(usize, usize)>,
    cmp: &mut impl FnMut(&T, &T) -> std::cmp::Ordering,
) {
    while runs.len() > 1 {
        let n = runs.len();
        if n >= 3 && runs[n - 3].1 <= runs[n - 2].1 + runs[n - 1].1 {
            if runs[n - 3].1 < runs[n - 1].1 {
                merge_at(v, runs, n - 3, cmp);
            } else {
                merge_at(v, runs, n - 2, cmp);
            }
        } else if runs[n - 2].1 <= runs[n - 1].1 {
            merge_at(v, runs, n - 2, cmp);
        } else {
            break;
        }
    }
}

/// Merge runs[i] and runs[i+1] (adjacent in v).
fn merge_at<T>(
    v: &mut [T],
    runs: &mut Vec<(usize, usize)>,
    i: usize,
    cmp: &mut impl FnMut(&T, &T) -> std::cmp::Ordering,
) {
    let (s1, l1) = runs[i];
    let (s2, l2) = runs[i + 1];
    debug_assert_eq!(s1 + l1, s2);
    merge_adjacent(&mut v[s1..s2 + l2], l1, cmp);
    runs[i] = (s1, l1 + l2);
    runs.remove(i + 1);
}

/// Galloping merge of v[..mid] and v[mid..], both sorted.
fn merge_adjacent<T>(v: &mut [T], mid: usize, cmp: &mut impl FnMut(&T, &T) -> std::cmp::Ordering) {
    let n = v.len();
    if mid == 0 || mid == n {
        return;
    }
    // temp copy of the left run (classic merge-lo; fine for our sizes)
    let mut tmp: Vec<T> = Vec::with_capacity(mid);
    // SAFETY-free approach: use Option slots via ManuallyDrop would be
    // unsafe; instead require T: Clone? No — do an index-based merge with
    // a scratch Vec by moving elements out through std::mem::replace with
    // a sentinel is impossible generically.  Use ptr reads safely via
    // Vec::drain-like approach:
    unsafe {
        tmp.set_len(0);
        tmp.reserve(mid);
        std::ptr::copy_nonoverlapping(v.as_ptr(), tmp.as_mut_ptr(), mid);
        tmp.set_len(mid);
        // v[..mid] is now logically moved out; we overwrite it below.
        let mut i = 0; // tmp index
        let mut j = mid; // right run index in v
        let mut d = 0; // destination in v
        let mut gallop_l = 0usize;
        let mut gallop_r = 0usize;
        while i < mid && j < n {
            let take_right = cmp(&*v.as_ptr().add(j), &*tmp.as_ptr().add(i)).is_lt();
            if take_right {
                let src = v.as_ptr().add(j);
                std::ptr::copy(src, v.as_mut_ptr().add(d), 1);
                j += 1;
                gallop_r += 1;
                gallop_l = 0;
            } else {
                std::ptr::copy_nonoverlapping(tmp.as_ptr().add(i), v.as_mut_ptr().add(d), 1);
                i += 1;
                gallop_l += 1;
                gallop_r = 0;
            }
            d += 1;
            // galloping mode: one side won MIN_GALLOP times in a row —
            // binary-search how far it keeps winning and copy in bulk.
            if gallop_l >= MIN_GALLOP && i < mid && j < n {
                let right_head = &*v.as_ptr().add(j);
                let run = gallop_count(&tmp[i..mid], |x| !cmp(right_head, x).is_lt());
                std::ptr::copy_nonoverlapping(tmp.as_ptr().add(i), v.as_mut_ptr().add(d), run);
                i += run;
                d += run;
                gallop_l = 0;
            } else if gallop_r >= MIN_GALLOP && i < mid && j < n {
                let left_head = &*tmp.as_ptr().add(i);
                // count right-run elements strictly less than left head
                let mut run = 0;
                while j + run < n && cmp(&*v.as_ptr().add(j + run), left_head).is_lt() {
                    run += 1;
                    if run >= 64 {
                        break; // bounded linear gallop; enough in practice
                    }
                }
                std::ptr::copy(v.as_ptr().add(j), v.as_mut_ptr().add(d), run);
                j += run;
                d += run;
                gallop_r = 0;
            }
        }
        if i < mid {
            std::ptr::copy_nonoverlapping(tmp.as_ptr().add(i), v.as_mut_ptr().add(d), mid - i);
        }
        // if j < n the tail is already in place
        tmp.set_len(0); // elements were moved into v; don't double-drop
    }
}

/// How many leading elements of sorted `xs` satisfy `pred` (pred is
/// monotone: true-prefix) — exponential probe + binary search.
fn gallop_count<T>(xs: &[T], mut pred: impl FnMut(&T) -> bool) -> usize {
    if xs.is_empty() || !pred(&xs[0]) {
        return 0;
    }
    let mut hi = 1;
    while hi < xs.len() && pred(&xs[hi]) {
        hi = (hi * 2).min(xs.len());
        if hi == xs.len() {
            break;
        }
    }
    let mut lo = hi / 2;
    let mut hi = hi.min(xs.len());
    while lo < hi {
        let mid = (lo + hi) / 2;
        if pred(&xs[mid]) {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn sorts_random() {
        let mut rng = Rng::new(1);
        for n in [0usize, 1, 2, 31, 32, 33, 100, 1_000, 50_000] {
            let mut v: Vec<u64> = (0..n).map(|_| rng.below(1000)).collect();
            let mut want = v.clone();
            want.sort();
            timsort_by(&mut v, |a, b| a.cmp(b));
            assert_eq!(v, want, "n={n}");
        }
    }

    #[test]
    fn sorts_adversarial_patterns() {
        for n in [100usize, 1000, 4096] {
            // sawtooth, organ pipe, sorted, reversed, constant
            let patterns: Vec<Vec<u64>> = vec![
                (0..n as u64).map(|i| i % 17).collect(),
                (0..n as u64).map(|i| (n as u64 / 2).abs_diff(i)).collect(),
                (0..n as u64).collect(),
                (0..n as u64).rev().collect(),
                vec![7; n],
            ];
            for mut v in patterns {
                let mut want = v.clone();
                want.sort();
                timsort_by(&mut v, |a, b| a.cmp(b));
                assert_eq!(v, want);
            }
        }
    }

    #[test]
    fn stability() {
        let mut rng = Rng::new(2);
        let mut v: Vec<(u64, usize)> =
            (0..5_000).map(|i| (rng.below(50), i)).collect();
        timsort_by(&mut v, |a, b| a.0.cmp(&b.0));
        for w in v.windows(2) {
            assert!(w[0].0 <= w[1].0);
            if w[0].0 == w[1].0 {
                assert!(w[0].1 < w[1].1, "stability violated");
            }
        }
    }

    #[test]
    fn by_key_api() {
        let mut v = vec![(3, "c"), (1, "a"), (2, "b")];
        timsort_by_key(&mut v, |x| x.0);
        assert_eq!(v, vec![(1, "a"), (2, "b"), (3, "c")]);
    }

    #[test]
    fn sorts_strings_no_drop_issues() {
        let mut rng = Rng::new(3);
        let mut v: Vec<String> =
            (0..2_000).map(|_| format!("key-{:06}", rng.below(500))).collect();
        let mut want = v.clone();
        want.sort();
        timsort_by(&mut v, |a, b| a.cmp(b));
        assert_eq!(v, want);
    }

    #[test]
    fn min_run_length_in_range() {
        for n in [32usize, 33, 63, 64, 1000, 1 << 20] {
            let m = min_run_length(n);
            assert!((16..=32).contains(&m), "n={n} minrun={m}");
        }
    }

    #[test]
    fn gallop_count_correct() {
        let xs = [1, 2, 3, 10, 20, 30];
        assert_eq!(gallop_count(&xs, |x| *x < 5), 3);
        assert_eq!(gallop_count(&xs, |x| *x < 1), 0);
        assert_eq!(gallop_count(&xs, |x| *x < 100), 6);
    }

    #[test]
    fn presorted_runs_detected_fast() {
        // mostly-sorted data with natural runs must still sort correctly
        let mut v: Vec<u64> = (0..10_000).collect();
        v[5_000] = 0;
        let mut want = v.clone();
        want.sort();
        timsort_by(&mut v, |a, b| a.cmp(b));
        assert_eq!(v, want);
    }
}
