//! Partitioned filter exchange: the two strategies that scale bloom
//! shipping past the broadcast wall.
//!
//! **SBFPJ — partitioned bloom join** ([`bloom_partitioned_join`]).
//! Broadcast ships every filter bit to every executor, so its network
//! cost grows as `filter_bytes × executors` — the "broadcast wall" that
//! makes huge dimension filters unaffordable on big clusters.  Here the
//! dimension's keys are hash-routed (`shuffle::partition_of`) into one
//! shard per node; each shard builds a filter over only its key range and
//! the filter is *placed* at its owner node's block manager instead of
//! broadcast.  Every filter bit crosses exactly one link, so shipping
//! divides by the cluster size rather than multiplying by it.  The fact
//! scan routes each probe key to its shard's filter (same hash, so a key
//! always meets the filter that saw its build-side twin — no false
//! negatives) and only the per-key verdict bitmap travels back.
//!
//! **SBFEJ — exchange bloom join** ([`bloom_exchange_join`]).  For
//! mutually selective edges the filtering is run in both directions: the
//! usual dimension filter prunes the fact side, then a *second* filter
//! built from the fact-side survivors travels back and prunes the
//! dimension before its payload is shuffled.  Two filter rounds buy a
//! smaller build-side shuffle; `plan::costing::exchange_cost_model`
//! prices when that trade wins.
//!
//! Both strategies reuse the cascade's shuffle + sort-merge tail and are
//! exact: filters may pass false positives (removed by the join) but
//! never drop a matching row.

use std::collections::HashSet;
use std::sync::Arc;

use crate::approx::approx_count;
use crate::bloom::{BloomFilter, BloomParams, KeyFilter, SelectionVector};
use crate::cluster::blockmanager::BlockManager;
use crate::cluster::shuffle::{partition_of, repartition, ShuffleCodec, ShuffleVolume};
use crate::cluster::{broadcast, Cluster, Cost, FaultKind, FaultSession, SimDuration, Stage, Task};
use crate::dataset::PartitionedTable;
use crate::metrics::{QueryMetrics, StageTiming};
use crate::plan::costing::shard_rebuild_price;

use super::sort_merge::sort_merge_join_partition;
use super::{JoinedRow, Keyed, RowSize};

/// A fault-aware partitioned run that could not finish: the seed-picked
/// node died mid-probe, taking its placed filter shard with it.  Carries
/// the simulated work already paid so the executor can book it (plus the
/// `degrade_broadcast` decision stage) before falling back to a plain
/// broadcast-filter bloom join at the same ε.
#[derive(Debug)]
pub struct PartitionedAbort {
    /// The node that was lost.
    pub node: usize,
    /// Stages completed before the loss (route/build/ship and any shard
    /// rebuild) — absorbed into the degraded edge's ledger.
    pub metrics: QueryMetrics,
}

/// Key-range-sharded bloom join: build one filter shard per node from
/// hash-routed dimension keys, place (not broadcast) each shard at its
/// owner, and route fact-side probe keys to the shard that can answer
/// them.
pub fn bloom_partitioned_join<B, S>(
    cluster: &Cluster,
    big: PartitionedTable<Keyed<B>>,
    small: PartitionedTable<Keyed<S>>,
    fpr: f64,
) -> (Vec<JoinedRow<B, S>>, QueryMetrics)
where
    B: Clone + Send + Sync + RowSize + 'static,
    S: Clone + Send + Sync + RowSize + 'static,
{
    match bloom_partitioned_join_faulted(cluster, big, small, fpr, None) {
        Ok(r) => r,
        Err(_) => unreachable!("fault-free partitioned runs never abort"),
    }
}

/// [`bloom_partitioned_join`] with a fault-injection session attached
/// (`cluster::faults`).  A fired shard eviction is recovered *in place*:
/// the evicted shard is rebuilt from its owning dimension partition's
/// retained keys (lineage) and re-shipped across its one link, booked as
/// the `shard_rebuild` recovery stage.  A fired node loss mid-probe is
/// not recoverable in place — the shard the probe needs is gone — so the
/// run returns [`PartitionedAbort`] with the partial ledger and the
/// caller degrades to a plain bloom join.  `faults: None` is
/// byte-for-byte the old behaviour and never aborts.
pub fn bloom_partitioned_join_faulted<B, S>(
    cluster: &Cluster,
    big: PartitionedTable<Keyed<B>>,
    small: PartitionedTable<Keyed<S>>,
    fpr: f64,
    faults: Option<&FaultSession>,
) -> Result<(Vec<JoinedRow<B, S>>, QueryMetrics), PartitionedAbort>
where
    B: Clone + Send + Sync + RowSize + 'static,
    S: Clone + Send + Sync + RowSize + 'static,
{
    let cfg = cluster.config().clone();
    let mut metrics = QueryMetrics::default();
    metrics.big_rows_scanned = big.n_rows() as u64;

    let shard_filters = build_shard_filters_faulted(cluster, &small, fpr, faults, &mut metrics);

    if let Some(fs) = faults {
        // injected fault: a node dies mid-probe, taking its placed shard
        // with it — not recoverable in place; hand back the partial
        // ledger so the caller can degrade the edge
        if fs.should_fire(FaultKind::NodeLoss, "probe") {
            let node = fs.target_index(cfg.n_nodes.max(1));
            return Err(PartitionedAbort { node, metrics });
        }
    }

    // -- step 5: sharded filter scan ---------------------------------------
    // each fact partition routes its keys with the *same* hash the build
    // used, probes shard-major, and streams only 8-byte keys out plus a
    // 1-bit-per-key verdict bitmap back
    let filters = Arc::new(shard_filters);
    let n_nodes = cfg.n_nodes;
    let tasks: Vec<Task<Vec<Keyed<B>>>> = big
        .into_partitions()
        .into_iter()
        .enumerate()
        .map(|(p, part)| {
            let filters = Arc::clone(&filters);
            let disk_bytes: u64 = part.iter().map(|(_, b)| 8 + b.row_bytes()).sum();
            let disk_s = disk_bytes as f64 / cfg.disk_bandwidth;
            let cpu_s = part.len() as f64 * cfg.scan_record_cost;
            let wire = 8 * part.len() as u64 + part.len() as u64 / 8;
            let net_s = wire as f64 / cfg.net_bandwidth;
            Task::new(move || {
                let n_shards = filters.len();
                let mut shard_keys: Vec<Vec<u64>> = vec![Vec::new(); n_shards];
                let mut shard_idx: Vec<Vec<u32>> = vec![Vec::new(); n_shards];
                for (i, (k, _)) in part.iter().enumerate() {
                    let s = partition_of(*k, n_shards);
                    shard_keys[s].push(*k);
                    shard_idx[s].push(i as u32);
                }
                let mut keep = vec![false; part.len()];
                let mut sel = SelectionVector::new();
                for ((filter, keys), idx) in filters.iter().zip(&shard_keys).zip(&shard_idx) {
                    filter.probe_batch(keys, &mut sel);
                    for &j in sel.indices() {
                        keep[idx[j as usize] as usize] = true;
                    }
                }
                let survivors: Vec<Keyed<B>> =
                    part.into_iter().zip(keep).filter_map(|(row, k)| k.then_some(row)).collect();
                let cost = Cost {
                    cpu_s,
                    net_s,
                    net_bytes: wire,
                    disk_s,
                    disk_bytes,
                    ..Default::default()
                };
                (survivors, cost)
            })
            .with_locality(p % n_nodes)
        })
        .collect();
    let scan = cluster.run_stage(Stage::new("filter_scan", tasks));
    let filtered: Vec<Vec<Keyed<B>>> = scan.outputs;
    metrics.big_rows_after_filter = filtered.iter().map(|p| p.len() as u64).sum();
    metrics.push(StageTiming {
        tasks: scan.n_tasks,
        wall_s: scan.wall_time.seconds(),
        cpu_s: scan.total_cost.cpu_s,
        net_bytes: scan.total_cost.net_bytes,
        disk_bytes: scan.total_cost.disk_bytes,
        ..StageTiming::new("filter_scan", scan.sim_time)
    });

    // -- step 6: shuffle + sort-merge join (cascade tail) ------------------
    let rows = shuffle_and_join(cluster, filtered, small.into_partitions(), &mut metrics);
    metrics.output_rows = rows.len() as u64;
    Ok((rows, metrics))
}

/// Steps 1–4 of the partitioned strategy — approximate count, key-range
/// shard routing, per-shard build at the owner node, one-link shard ship,
/// plus the in-place `ShardEviction` lineage rebuild — booked into
/// `metrics`, without the probe/shuffle/join tail.  Shared by
/// [`bloom_partitioned_join_faulted`] and the fused probe pipeline's
/// build stage (which additionally pays a `shard_fetch` to make every
/// shard resident on the probing nodes and leaves `NodeLoss` handling to
/// its group-eligibility rules).
pub(crate) fn build_shard_filters_faulted<S>(
    cluster: &Cluster,
    small: &PartitionedTable<Keyed<S>>,
    fpr: f64,
    faults: Option<&FaultSession>,
    metrics: &mut QueryMetrics,
) -> Vec<BloomFilter> {
    let cfg = cluster.config().clone();
    metrics.requested_fpr = fpr;

    // -- step 1: approximate count ----------------------------------------
    let sizes: Vec<usize> = small.partitions().iter().map(Vec::len).collect();
    let est = approx_count(&cfg, &sizes, 2.0, 2e-8);
    metrics.push(StageTiming {
        tasks: est.partitions_seen,
        ..StageTiming::new("approx_count", SimDuration::from_secs(est.sim_s))
    });

    // -- step 2: route dimension keys to their shard -----------------------
    // one shard per node; only the 8-byte keys travel, priced as a
    // repartition exchange (the partitioned strategy's extra K1 term)
    let n_shards = cfg.n_nodes.max(1);
    let mut shard_keys: Vec<Vec<u64>> = vec![Vec::new(); n_shards];
    let mut route_vol = ShuffleVolume { records: 0, bytes: 0, partitions_out: n_shards };
    for part in small.partitions() {
        for (k, _) in part {
            route_vol.records += 1;
            route_vol.bytes += 8;
            shard_keys[partition_of(*k, n_shards)].push(*k);
        }
    }
    let route_cost = route_vol.exchange_cost(&cfg, ShuffleCodec::Tungsten);
    metrics.push(
        StageTiming {
            tasks: n_shards,
            ..StageTiming::new(
                "shard_route",
                SimDuration::from_secs(route_cost.total_seconds(cfg.cpu_scale)),
            )
        }
        .with_cost(&route_cost),
    );

    // -- step 3: per-shard filter build ------------------------------------
    // each shard sizes for its slice of the estimate and builds where the
    // filter will live (locality = the shard's owner node)
    let params = BloomParams::sharded(est.estimate.max(1), n_shards, fpr);
    // lineage: an eviction plan retains each shard's routed key slice so
    // a lost shard can be rebuilt from its owning dimension partition
    let lineage: Option<Vec<Vec<u64>>> = faults
        .filter(|fs| fs.plan().count_of(FaultKind::ShardEviction) > 0)
        .map(|_| shard_keys.clone());
    let tasks: Vec<Task<BloomFilter>> = shard_keys
        .into_iter()
        .enumerate()
        .map(|(s, keys)| {
            let hash_c = cfg.hash_insert_cost;
            let scan_c = cfg.scan_record_cost;
            Task::new(move || {
                let cpu_s = keys.len() as f64 * (scan_c + hash_c * params.k as f64);
                let mut f = BloomFilter::new(params);
                for k in keys {
                    f.insert(k);
                }
                (f, Cost { cpu_s, ..Default::default() })
            })
            .with_locality(s % cfg.n_nodes)
        })
        .collect();
    let build = cluster.run_stage(Stage::new("shard_build", tasks));
    let mut shard_filters = build.outputs;
    metrics.bloom_bits = params.m_bits * n_shards as u64;
    metrics.realized_fpr = params.realized_fpr((small.n_rows() / n_shards).max(1) as u64);
    metrics.push(StageTiming {
        tasks: build.n_tasks,
        wall_s: build.wall_time.seconds(),
        cpu_s: build.total_cost.cpu_s,
        ..StageTiming::new("shard_build", build.sim_time)
    });

    // -- step 4: place each shard at its owner node ------------------------
    // no broadcast: every filter byte crosses one link, per-node links in
    // parallel, and the shard parks in its node's block manager.  (The
    // cluster's own managers need `&mut`; a per-query placement ledger
    // keeps the accounting honest.)
    let shard_bytes: Vec<u64> = shard_filters.iter().map(|f| f.to_bytes().len() as u64).collect();
    let total_fb: u64 = shard_bytes.iter().sum();
    let mut managers: Vec<BlockManager> =
        (0..cfg.n_nodes).map(|n| BlockManager::new(n, cfg.executor_mem_bytes)).collect();
    let mut spilled = 0u64;
    for (s, &fb) in shard_bytes.iter().enumerate() {
        if !managers[s % cfg.n_nodes].put(format!("filter-shard-{s}"), fb) {
            spilled += fb; // over the executor budget: spilled, re-read from disk
        }
    }
    let per_shard = (total_fb / n_shards as u64).max(1);
    let ship = SimDuration::from_secs(cfg.transfer_seconds(per_shard) + cfg.net_latency);
    metrics.push(StageTiming { tasks: n_shards, ..StageTiming::new("shard_ship", ship) }.with_cost(
        &Cost { net_bytes: total_fb, disk_bytes: spilled, ..Default::default() },
    ));

    if let Some(fs) = faults {
        // injected fault: one shard evicted from its owner's BlockManager
        // between placement and probe — rebuild it from the retained
        // lineage keys and re-ship it across its one link
        if fs.should_fire(FaultKind::ShardEviction, "shard_ship") {
            let victim = fs.target_index(n_shards);
            let keys = &lineage.as_ref().expect("eviction plans retain lineage")[victim];
            let mut rebuilt = BloomFilter::new(params);
            for &k in keys {
                rebuilt.insert(k);
            }
            shard_filters[victim] = rebuilt;
            let (sim, cost) = shard_rebuild_price(&cfg, keys.len() as u64, shard_bytes[victim]);
            metrics.push(
                StageTiming { tasks: 1, ..StageTiming::new("shard_rebuild", sim) }.with_cost(&cost),
            );
            fs.log_recovery(
                "shard_rebuild",
                "shard_ship",
                format!("shard {victim} evicted; rebuilt from {} retained keys", keys.len()),
                sim.seconds(),
            );
        }
    }

    shard_filters
}

/// Two-round exchange bloom join: the usual dimension filter prunes the
/// fact side, then a filter over the fact-side *survivors* travels back
/// and prunes the dimension before its payload ships.
pub fn bloom_exchange_join<B, S>(
    cluster: &Cluster,
    big: PartitionedTable<Keyed<B>>,
    small: PartitionedTable<Keyed<S>>,
    fpr: f64,
) -> (Vec<JoinedRow<B, S>>, QueryMetrics)
where
    B: Clone + Send + Sync + RowSize + 'static,
    S: Clone + Send + Sync + RowSize + 'static,
{
    let cfg = cluster.config().clone();
    let mut metrics = QueryMetrics::default();
    metrics.requested_fpr = fpr;
    metrics.big_rows_scanned = big.n_rows() as u64;

    // -- round 1: the cascade's build + broadcast + filtered scan ----------
    let sizes: Vec<usize> = small.partitions().iter().map(Vec::len).collect();
    let est = approx_count(&cfg, &sizes, 2.0, 2e-8);
    metrics.push(StageTiming {
        tasks: est.partitions_seen,
        ..StageTiming::new("approx_count", SimDuration::from_secs(est.sim_s))
    });

    let params = BloomParams::optimal(est.estimate.max(1), fpr);
    let key_parts: Vec<Vec<u64>> =
        small.partitions().iter().map(|p| p.iter().map(|(k, _)| *k).collect()).collect();
    let (filter, timing) = distributed_filter_build(cluster, key_parts, params, "bloom_build");
    metrics.bloom_bits = params.m_bits;
    metrics.realized_fpr = params.realized_fpr(small.n_rows() as u64);
    metrics.push(timing);

    let filter_bytes = filter.to_bytes().len() as u64;
    let bc = broadcast::p2p_broadcast_cost(&cfg, filter_bytes);
    metrics.push(StageTiming::new("broadcast", bc).with_cost(&Cost {
        net_bytes: filter_bytes * cfg.total_executors() as u64,
        ..Default::default()
    }));

    let filter = Arc::new(filter);
    let n_nodes = cfg.n_nodes;
    let tasks: Vec<Task<Vec<Keyed<B>>>> = big
        .into_partitions()
        .into_iter()
        .enumerate()
        .map(|(p, part)| {
            let filter = Arc::clone(&filter);
            let disk_bytes: u64 = part.iter().map(|(_, b)| 8 + b.row_bytes()).sum();
            let disk_s = disk_bytes as f64 / cfg.disk_bandwidth;
            let cpu_s = part.len() as f64 * cfg.scan_record_cost;
            Task::new(move || {
                let keys: Vec<u64> = part.iter().map(|(k, _)| *k).collect();
                let mut sel = SelectionVector::with_capacity(keys.len());
                filter.probe_batch(&keys, &mut sel);
                (sel.gather_owned(part), Cost { cpu_s, disk_s, disk_bytes, ..Default::default() })
            })
            .with_locality(p % n_nodes)
        })
        .collect();
    let scan = cluster.run_stage(Stage::new("filter_scan", tasks));
    let filtered: Vec<Vec<Keyed<B>>> = scan.outputs;
    metrics.big_rows_after_filter = filtered.iter().map(|p| p.len() as u64).sum();
    metrics.push(StageTiming {
        tasks: scan.n_tasks,
        wall_s: scan.wall_time.seconds(),
        cpu_s: scan.total_cost.cpu_s,
        disk_bytes: scan.total_cost.disk_bytes,
        ..StageTiming::new("filter_scan", scan.sim_time)
    });

    // -- round 2: survivor filter back-prunes the build side ---------------
    // sized for the survivors' distinct keys; built where the survivors
    // already sit, so only the (small) survivor filter travels
    let distinct: HashSet<u64> =
        filtered.iter().flat_map(|p| p.iter().map(|(k, _)| *k)).collect();
    let sf_params = BloomParams::optimal(distinct.len().max(1) as u64, fpr);
    let survivor_keys: Vec<Vec<u64>> =
        filtered.iter().map(|p| p.iter().map(|(k, _)| *k).collect()).collect();
    let (sf, sf_timing) =
        distributed_filter_build(cluster, survivor_keys, sf_params, "exchange_build");
    metrics.bloom_bits += sf_params.m_bits;
    metrics.push(sf_timing);

    let sf = Arc::new(sf);
    let sf_bytes = sf.to_bytes().len() as u64;
    let back = broadcast::p2p_broadcast_cost(&cfg, sf_bytes);
    let tasks: Vec<Task<Vec<Keyed<S>>>> = small
        .into_partitions()
        .into_iter()
        .enumerate()
        .map(|(p, part)| {
            let sf = Arc::clone(&sf);
            let cpu_s = part.len() as f64 * cfg.scan_record_cost;
            Task::new(move || {
                let keys: Vec<u64> = part.iter().map(|(k, _)| *k).collect();
                let mut sel = SelectionVector::with_capacity(keys.len());
                sf.probe_batch(&keys, &mut sel);
                (sel.gather_owned(part), Cost { cpu_s, ..Default::default() })
            })
            .with_locality(p % n_nodes)
        })
        .collect();
    let prune = cluster.run_stage(Stage::new("exchange_ship", tasks));
    let pruned: Vec<Vec<Keyed<S>>> = prune.outputs;
    metrics.push(StageTiming {
        tasks: prune.n_tasks,
        wall_s: prune.wall_time.seconds(),
        cpu_s: prune.total_cost.cpu_s,
        net_bytes: sf_bytes * cfg.total_executors() as u64,
        ..StageTiming::new("exchange_ship", back + prune.sim_time)
    });

    // -- shuffle + sort-merge join over both pruned sides ------------------
    let rows = shuffle_and_join(cluster, filtered, pruned, &mut metrics);
    metrics.output_rows = rows.len() as u64;
    (rows, metrics)
}

/// Per-partition partial filter build + driver tree OR-merge (the
/// cascade's §5.1 distributed build, shared by both exchange rounds).
fn distributed_filter_build(
    cluster: &Cluster,
    key_parts: Vec<Vec<u64>>,
    params: BloomParams,
    stage_name: &'static str,
) -> (BloomFilter, StageTiming) {
    let cfg = cluster.config();
    let tasks: Vec<Task<BloomFilter>> = key_parts
        .into_iter()
        .map(|keys| {
            let hash_c = cfg.hash_insert_cost;
            let scan_c = cfg.scan_record_cost;
            Task::new(move || {
                let cpu_s = keys.len() as f64 * (scan_c + hash_c * params.k as f64);
                let mut f = BloomFilter::new(params);
                for k in keys {
                    f.insert(k);
                }
                (f, Cost { cpu_s, ..Default::default() })
            })
        })
        .collect();
    let stage = cluster.run_stage(Stage::new(stage_name, tasks));

    let t0 = std::time::Instant::now();
    let mut it = stage.outputs.into_iter();
    let mut merged = it.next().unwrap_or_else(|| BloomFilter::new(params));
    for partial in it {
        merged.merge(&partial).expect("identical params by construction");
    }
    let merge_cpu = t0.elapsed().as_secs_f64();
    let collect = broadcast::driver_collect_cost(cfg, params.size_bytes());

    let sim = stage.sim_time + collect + SimDuration::from_secs(merge_cpu * cfg.cpu_scale);
    let timing = StageTiming {
        tasks: stage.n_tasks,
        wall_s: stage.wall_time.seconds() + merge_cpu,
        cpu_s: stage.total_cost.cpu_s + merge_cpu,
        net_bytes: params.size_bytes() * stage.n_tasks as u64,
        ..StageTiming::new(stage_name, sim)
    };
    (merged, timing)
}

/// The cascade's tail: 200-partition shuffle of both (already filtered)
/// sides plus the per-partition sort-merge join, with the usual
/// accounting.  `pub(crate)` so the fused probe pipeline's late
/// materialisation step can reuse the exact tail each unfused edge runs.
pub(crate) fn shuffle_and_join<B, S>(
    cluster: &Cluster,
    filtered: Vec<Vec<Keyed<B>>>,
    small_parts: Vec<Vec<Keyed<S>>>,
    metrics: &mut QueryMetrics,
) -> Vec<JoinedRow<B, S>>
where
    B: Clone + Send + Sync + RowSize + 'static,
    S: Clone + Send + Sync + RowSize + 'static,
{
    let cfg = cluster.config().clone();
    let n_shuffle = cfg.shuffle_partitions;
    let (big_buckets, big_vol) = repartition(filtered, n_shuffle, |b: &B| b.row_bytes());
    let (small_buckets, small_vol) = repartition(small_parts, n_shuffle, |s: &S| s.row_bytes());
    let mut ex_cost = big_vol.exchange_cost(&cfg, ShuffleCodec::Tungsten);
    ex_cost.merge(&small_vol.exchange_cost(&cfg, ShuffleCodec::Tungsten));
    metrics.push(
        StageTiming {
            tasks: n_shuffle,
            ..StageTiming::new(
                "shuffle",
                SimDuration::from_secs(ex_cost.total_seconds(cfg.cpu_scale)),
            )
        }
        .with_cost(&ex_cost),
    );

    let tasks: Vec<Task<Vec<JoinedRow<B, S>>>> = big_buckets
        .into_iter()
        .zip(small_buckets)
        .map(|(b, s)| {
            let disk_bw = cfg.disk_bandwidth;
            let sort_c = cfg.sort_compare_cost;
            let merge_c = cfg.merge_record_cost;
            Task::new(move || {
                let nlogn =
                    |n: usize| if n < 2 { n as f64 } else { n as f64 * (n as f64).log2() };
                let cpu_s = sort_c * (nlogn(b.len()) + nlogn(s.len()))
                    + merge_c * (b.len() + s.len()) as f64;
                let out = sort_merge_join_partition(b, s);
                let cpu_s = cpu_s + merge_c * out.len() as f64;
                let write_bytes: u64 =
                    out.iter().map(|(_, b, s)| 8 + b.row_bytes() + s.row_bytes()).sum();
                let disk_s = write_bytes as f64 / disk_bw;
                (out, Cost { cpu_s, disk_s, disk_bytes: write_bytes, ..Default::default() })
            })
        })
        .collect();
    let join = cluster.run_stage(Stage::new("join", tasks));
    let rows: Vec<JoinedRow<B, S>> = join.outputs.into_iter().flatten().collect();
    metrics.push(StageTiming {
        tasks: join.n_tasks,
        wall_s: join.wall_time.seconds(),
        cpu_s: join.total_cost.cpu_s,
        disk_bytes: join.total_cost.disk_bytes,
        ..StageTiming::new("join", join.sim_time)
    });
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;
    use crate::joins::{BloomCascadeConfig, BloomCascadeJoin};
    use crate::util::Rng;

    fn inputs(
        n_big: usize,
        n_small: usize,
        big_space: u64,
        small_space: u64,
    ) -> (PartitionedTable<Keyed<u64>>, PartitionedTable<Keyed<u64>>) {
        let mut rng = Rng::new(42);
        let big: Vec<Keyed<u64>> =
            (0..n_big).map(|_| (rng.below(big_space), rng.next_u64())).collect();
        let small: Vec<Keyed<u64>> =
            (0..n_small).map(|_| (rng.below(small_space), rng.next_u64())).collect();
        (PartitionedTable::from_rows(big, 4), PartitionedTable::from_rows(small, 2))
    }

    fn oracle_count(
        big: &PartitionedTable<Keyed<u64>>,
        small: &PartitionedTable<Keyed<u64>>,
    ) -> usize {
        use std::collections::HashMap;
        let mut sc: HashMap<u64, usize> = HashMap::new();
        for (k, _) in small.iter() {
            *sc.entry(*k).or_default() += 1;
        }
        big.iter().map(|(k, _)| sc.get(k).copied().unwrap_or(0)).sum()
    }

    #[test]
    fn partitioned_produces_exact_join_result() {
        // multi-node config: real sharding (8 shards), not the degenerate
        // single-shard case
        let cluster = Cluster::new(ClusterConfig::default());
        let (big, small) = inputs(2_000, 200, 10_000, 1_000);
        let want = oracle_count(&big, &small);
        let (rows, metrics) = bloom_partitioned_join(&cluster, big, small, 0.05);
        assert_eq!(rows.len(), want);
        assert_eq!(metrics.output_rows as usize, want);
    }

    #[test]
    fn partitioned_exact_on_single_node_too() {
        let cluster = Cluster::new(ClusterConfig::local());
        let (big, small) = inputs(1_500, 150, 5_000, 500);
        let want = oracle_count(&big, &small);
        let (rows, _) = bloom_partitioned_join(&cluster, big, small, 0.05);
        assert_eq!(rows.len(), want);
    }

    #[test]
    fn exchange_produces_exact_join_result() {
        let cluster = Cluster::new(ClusterConfig::local());
        let (big, small) = inputs(2_000, 200, 10_000, 1_000);
        let want = oracle_count(&big, &small);
        let (rows, metrics) = bloom_exchange_join(&cluster, big, small, 0.05);
        assert_eq!(rows.len(), want);
        assert_eq!(metrics.output_rows as usize, want);
    }

    #[test]
    fn partitioned_filter_actually_filters() {
        let cluster = Cluster::new(ClusterConfig::default());
        let (big, small) = inputs(5_000, 100, 100_000, 10_000);
        let scanned = big.n_rows() as u64;
        let (_, metrics) = bloom_partitioned_join(&cluster, big, small, 0.01);
        assert_eq!(metrics.big_rows_scanned, scanned);
        assert!(
            metrics.big_rows_after_filter < scanned / 2,
            "{} of {scanned} survived",
            metrics.big_rows_after_filter
        );
    }

    #[test]
    fn partitioned_has_its_stages() {
        let cluster = Cluster::new(ClusterConfig::default());
        let (big, small) = inputs(500, 50, 1_000, 100);
        let (_, metrics) = bloom_partitioned_join(&cluster, big, small, 0.05);
        for stage in [
            "approx_count",
            "shard_route",
            "shard_build",
            "shard_ship",
            "filter_scan",
            "shuffle",
            "join",
        ] {
            assert!(metrics.stage(stage).is_some(), "missing {stage}");
        }
        assert!(metrics.stage("broadcast").is_none(), "partitioned must not broadcast");
        assert!(metrics.bloom_creation_s() > 0.0);
        assert!(metrics.filter_join_s() > 0.0);
        assert!(metrics.bloom_bits > 0);
    }

    #[test]
    fn exchange_has_its_stages() {
        let cluster = Cluster::new(ClusterConfig::local());
        let (big, small) = inputs(500, 50, 1_000, 100);
        let (_, metrics) = bloom_exchange_join(&cluster, big, small, 0.05);
        for stage in [
            "approx_count",
            "bloom_build",
            "broadcast",
            "filter_scan",
            "exchange_build",
            "exchange_ship",
            "shuffle",
            "join",
        ] {
            assert!(metrics.stage(stage).is_some(), "missing {stage}");
        }
        assert!(metrics.bloom_creation_s() > 0.0);
        assert!(metrics.filter_join_s() > 0.0);
    }

    #[test]
    fn partitioned_ships_fewer_filter_bytes_than_broadcast() {
        // 8 nodes × 2 executors: broadcast pays filter × 16, sharding
        // pays each filter byte once
        let cfg = ClusterConfig::default();
        let (big, small) = inputs(20_000, 2_000, 50_000, 5_000);
        let want = oracle_count(&big, &small);

        let cluster = Cluster::new(cfg);
        let cascade = BloomCascadeJoin::new(BloomCascadeConfig { fpr: 0.05, ..Default::default() });
        let (b_rows, b_metrics) = cascade.execute(&cluster, big.clone(), small.clone());
        let (p_rows, p_metrics) = bloom_partitioned_join(&cluster, big, small, 0.05);

        assert_eq!(b_rows.len(), want);
        assert_eq!(p_rows.len(), want);
        let broadcast_bytes = b_metrics.stage("broadcast").unwrap().net_bytes;
        let shipped = p_metrics.stage("shard_ship").unwrap().net_bytes;
        assert!(
            shipped < broadcast_bytes,
            "sharded ship {shipped} must beat broadcast {broadcast_bytes}"
        );
    }

    #[test]
    fn exchange_prunes_the_build_side_before_the_shuffle() {
        // mutually selective: most small keys never meet a surviving big
        // row, so the survivor filter shrinks the build-side shuffle
        let cfg = ClusterConfig::local();
        let mut rng = Rng::new(7);
        let big: Vec<Keyed<u64>> =
            (0..10_000).map(|_| (rng.below(2_000), rng.next_u64())).collect();
        let small: Vec<Keyed<u64>> =
            (0..5_000).map(|_| (rng.below(100_000), rng.next_u64())).collect();
        let big = PartitionedTable::from_rows(big, 4);
        let small = PartitionedTable::from_rows(small, 2);
        let want = oracle_count(&big, &small);

        let cluster = Cluster::new(cfg);
        let cascade = BloomCascadeJoin::new(BloomCascadeConfig { fpr: 0.01, ..Default::default() });
        let (c_rows, c_metrics) = cascade.execute(&cluster, big.clone(), small.clone());
        let (e_rows, e_metrics) = bloom_exchange_join(&cluster, big, small, 0.01);

        assert_eq!(c_rows.len(), want);
        assert_eq!(e_rows.len(), want, "back-pruning must not change the result");
        let c_shuffle = c_metrics.stage("shuffle").unwrap().net_bytes;
        let e_shuffle = e_metrics.stage("shuffle").unwrap().net_bytes;
        assert!(
            e_shuffle < c_shuffle,
            "exchange shuffle {e_shuffle} must beat cascade shuffle {c_shuffle}"
        );
    }

    #[test]
    fn shard_eviction_rebuilds_from_lineage_bit_identical() {
        use crate::cluster::{FaultPlan, FaultSession};
        let cluster = Cluster::new(ClusterConfig::default());
        let (big, small) = inputs(2_000, 200, 10_000, 1_000);
        let (clean_rows, clean_m) =
            bloom_partitioned_join(&cluster, big.clone(), small.clone(), 0.05);
        assert_eq!(clean_m.recovery_s(), 0.0);

        let fs = FaultSession::new(FaultPlan::parse("shard-loss").unwrap());
        let (rows, m) = bloom_partitioned_join_faulted(&cluster, big, small, 0.05, Some(&fs))
            .expect("an evicted shard is recoverable in place");
        assert_eq!(rows, clean_rows, "lineage rebuild must be bit-identical");
        let rb = m.stage("shard_rebuild").expect("rebuild booked");
        assert!(rb.net_bytes > 0, "the rebuilt shard re-ships across one link");
        assert_eq!(m.total_net_bytes(), clean_m.total_net_bytes() + rb.net_bytes);
        assert!(m.stage("broadcast").is_none(), "recovery must not broadcast");
        assert_eq!(fs.injected().len(), 1);
        assert_eq!(fs.recovered().len(), 1);
    }

    #[test]
    fn node_loss_aborts_with_partial_metrics() {
        use crate::cluster::{FaultPlan, FaultSession};
        let cluster = Cluster::new(ClusterConfig::default());
        let (big, small) = inputs(500, 50, 1_000, 100);
        let fs = FaultSession::new(FaultPlan::parse("node-loss").unwrap());
        let abort = bloom_partitioned_join_faulted(&cluster, big, small, 0.05, Some(&fs))
            .expect_err("a lost node mid-probe cannot be finished in place");
        assert!(abort.node < ClusterConfig::default().n_nodes);
        for stage in ["approx_count", "shard_route", "shard_build", "shard_ship"] {
            assert!(abort.metrics.stage(stage).is_some(), "partial ledger keeps {stage}");
        }
        assert!(abort.metrics.stage("filter_scan").is_none(), "the probe never ran");
    }

    #[test]
    fn shard_routing_is_build_probe_consistent() {
        // the invariant exactness rests on: build and probe route any key
        // to the same shard, for every shard count
        for n in [1usize, 4, 8, 64] {
            for key in [0u64, 1, 42, 6_000_000, u64::MAX] {
                assert_eq!(partition_of(key, n), partition_of(key, n), "key {key} shards {n}");
                assert!(partition_of(key, n) < n.max(1));
            }
        }
    }
}
