//! Generic binary join executors: the broadcast-hash and sort-merge
//! strategies as free functions over any keyed payload types.
//!
//! Extracted from `query.rs` so both the paper's two-table [`JoinQuery`]
//! and the multi-way [`plan`] executor dispatch the same stage
//! implementations — one cost-accounting code path per strategy, however
//! many edges a plan has.  The bloom-cascade strategy already lives in
//! [`bloom_cascade::BloomCascadeJoin::execute`], which is equally generic.
//!
//! [`JoinQuery`]: crate::query::JoinQuery
//! [`plan`]: crate::plan
//! [`bloom_cascade::BloomCascadeJoin::execute`]: crate::joins::bloom_cascade::BloomCascadeJoin::execute

use std::sync::Arc;

use crate::cluster::shuffle::{repartition, ShuffleCodec};
use crate::cluster::{broadcast, Cluster, Cost, SimDuration, Stage, Task};
use crate::metrics::{QueryMetrics, StageTiming};

use super::broadcast_hash::{broadcast_bytes, build_hash_table, probe_partition};
use super::sort_merge::sort_merge_join_partition;
use super::{JoinedRow, Keyed, RowSize};
use crate::dataset::PartitionedTable;

/// Gather a column through a selection (survivor indices, repeats legal
/// for one-to-many joins) — the building block of the plan executor's
/// selection-vector stream representation: edges pass indices + appended
/// payload columns downstream instead of cloned rows, and composing two
/// selections is just gathering the outer one through the inner.
#[inline]
pub fn gather<T: Copy>(col: &[T], sel: &[u32]) -> Vec<T> {
    sel.iter().map(|&i| col[i as usize]).collect()
}

/// Spark's `BroadcastHashJoin` (SBJ): collect + broadcast the small side,
/// build a hash table per executor, stream the big side through it.
pub fn broadcast_hash_join<B, S>(
    cluster: &Cluster,
    big: PartitionedTable<Keyed<B>>,
    small: PartitionedTable<Keyed<S>>,
) -> (Vec<JoinedRow<B, S>>, QueryMetrics)
where
    B: Clone + Send + Sync + RowSize + 'static,
    S: Clone + Send + Sync + RowSize + 'static,
{
    let cfg = cluster.config().clone();
    let mut metrics = QueryMetrics::default();
    metrics.big_rows_scanned = big.n_rows() as u64;

    // collect small table to driver, broadcast to all executors
    let small_rows: Vec<Keyed<S>> = small.into_rows();
    let payload = broadcast_bytes(&small_rows);
    let collect = broadcast::driver_collect_cost(&cfg, payload);
    let bc = broadcast::p2p_broadcast_cost(&cfg, payload);
    metrics.push(StageTiming::new("broadcast", collect + bc).with_cost(&Cost {
        net_bytes: payload * (cfg.total_executors() as u64 + 1),
        ..Default::default()
    }));

    // every executor builds the hash table from the broadcast payload
    // once; modeled at merge_record_cost per row (spread over slots as
    // one warm-up task per executor is approximated by adding it to
    // each scan task's first-touch cost share)
    let table = Arc::new(build_hash_table(&small_rows));
    let table_build_cpu = small_rows.len() as f64 * cfg.merge_record_cost;
    let n_nodes = cfg.n_nodes;
    let n_tasks_total = big.n_partitions().max(1);
    let tasks: Vec<Task<Vec<JoinedRow<B, S>>>> = big
        .into_partitions()
        .into_iter()
        .enumerate()
        .map(|(p, part)| {
            let table = Arc::clone(&table);
            let disk_bytes: u64 = part.iter().map(|(_, b)| 8 + b.row_bytes()).sum();
            let disk_s = disk_bytes as f64 / cfg.disk_bandwidth;
            // modeled JVM scan + hash-probe cost (see ClusterConfig)
            let cpu_s = part.len() as f64 * cfg.scan_record_cost
                + table_build_cpu / n_tasks_total as f64;
            let merge_c = cfg.merge_record_cost;
            Task::new(move || {
                let out = probe_partition(&part, &table);
                let cpu_s = cpu_s + out.len() as f64 * merge_c;
                (out, Cost { cpu_s, disk_s, disk_bytes, ..Default::default() })
            })
            .with_locality(p % n_nodes)
        })
        .collect();
    let scan = cluster.run_stage(Stage::new("join", tasks));
    let rows: Vec<_> = scan.outputs.into_iter().flatten().collect();
    metrics.push(StageTiming {
        tasks: scan.n_tasks,
        wall_s: scan.wall_time.seconds(),
        cpu_s: scan.total_cost.cpu_s,
        disk_bytes: scan.total_cost.disk_bytes,
        ..StageTiming::new("join", scan.sim_time)
    });
    metrics.output_rows = rows.len() as u64;
    metrics.big_rows_after_filter = metrics.big_rows_scanned; // no pre-filter
    (rows, metrics)
}

/// Plain shuffle + sort-merge join (Spark's large-large default).
pub fn sort_merge_join<B, S>(
    cluster: &Cluster,
    big: PartitionedTable<Keyed<B>>,
    small: PartitionedTable<Keyed<S>>,
) -> (Vec<JoinedRow<B, S>>, QueryMetrics)
where
    B: Clone + Send + Sync + RowSize + 'static,
    S: Clone + Send + Sync + RowSize + 'static,
{
    let cfg = cluster.config().clone();
    let mut metrics = QueryMetrics::default();
    metrics.big_rows_scanned = big.n_rows() as u64;
    metrics.big_rows_after_filter = metrics.big_rows_scanned;

    // scan stage: read both tables (disk + modeled per-record scan
    // cpu spread over the cluster; WHERE already fused)
    let scan_bytes: u64 = big.ser_bytes(|(_, b)| 8 + b.row_bytes())
        + small.ser_bytes(|(_, s)| 8 + s.row_bytes());
    let scan_cpu = (big.n_rows() + small.n_rows()) as f64 * cfg.scan_record_cost
        / cfg.total_slots().max(1) as f64;
    metrics.push(
        StageTiming::new(
            "filter_scan",
            SimDuration::from_secs(
                cfg.disk_seconds(scan_bytes / cfg.n_nodes.max(1) as u64)
                    + scan_cpu
                    + cfg.stage_overhead,
            ),
        )
        .with_cost(&Cost { disk_bytes: scan_bytes, cpu_s: scan_cpu, ..Default::default() }),
    );

    let n_shuffle = cfg.shuffle_partitions;
    let (big_buckets, big_vol) =
        repartition(big.into_partitions(), n_shuffle, |b: &B| b.row_bytes());
    let (small_buckets, small_vol) =
        repartition(small.into_partitions(), n_shuffle, |s: &S| s.row_bytes());
    let mut ex = big_vol.exchange_cost(&cfg, ShuffleCodec::Tungsten);
    ex.merge(&small_vol.exchange_cost(&cfg, ShuffleCodec::Tungsten));
    metrics.push(
        StageTiming {
            tasks: n_shuffle,
            ..StageTiming::new("shuffle", SimDuration::from_secs(ex.total_seconds(cfg.cpu_scale)))
        }
        .with_cost(&ex),
    );

    let tasks: Vec<Task<Vec<JoinedRow<B, S>>>> = big_buckets
        .into_iter()
        .zip(small_buckets)
        .map(|(b, s)| {
            let sort_c = cfg.sort_compare_cost;
            let merge_c = cfg.merge_record_cost;
            let disk_bw = cfg.disk_bandwidth;
            Task::new(move || {
                let nlogn = |n: usize| {
                    if n < 2 { n as f64 } else { n as f64 * (n as f64).log2() }
                };
                let cpu_s = sort_c * (nlogn(b.len()) + nlogn(s.len()))
                    + merge_c * (b.len() + s.len()) as f64;
                let out = sort_merge_join_partition(b, s);
                let cpu_s = cpu_s + merge_c * out.len() as f64;
                let bytes: u64 = out.len() as u64 * 20;
                (
                    out,
                    Cost { cpu_s, disk_s: bytes as f64 / disk_bw, disk_bytes: bytes, ..Default::default() },
                )
            })
        })
        .collect();
    let join = cluster.run_stage(Stage::new("join", tasks));
    let rows: Vec<_> = join.outputs.into_iter().flatten().collect();
    metrics.push(StageTiming {
        tasks: join.n_tasks,
        wall_s: join.wall_time.seconds(),
        cpu_s: join.total_cost.cpu_s,
        disk_bytes: join.total_cost.disk_bytes,
        ..StageTiming::new("join", join.sim_time)
    });
    metrics.output_rows = rows.len() as u64;
    (rows, metrics)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;
    use crate::util::Rng;

    fn inputs() -> (PartitionedTable<Keyed<u64>>, PartitionedTable<Keyed<u32>>) {
        let mut rng = Rng::new(17);
        let big: Vec<Keyed<u64>> = (0..3_000).map(|_| (rng.below(900), rng.next_u64())).collect();
        let small: Vec<Keyed<u32>> = (0..400).map(|_| (rng.below(900), rng.next_u32())).collect();
        (PartitionedTable::from_rows(big, 5), PartitionedTable::from_rows(small, 3))
    }

    #[test]
    fn gather_composes_selections() {
        let col = [10u64, 20, 30, 40];
        // one-to-many edges may select an index twice
        let sel1 = [0u32, 2, 2, 3];
        let stage1 = gather(&col, &sel1);
        assert_eq!(stage1, vec![10, 30, 30, 40]);
        // composing selections == gathering the outer through the inner
        let sel2 = [1u32, 3];
        let composed = gather(&sel1, &sel2);
        assert_eq!(gather(&col, &composed), gather(&stage1, &sel2));
    }

    #[test]
    fn broadcast_and_sort_merge_agree() {
        let cluster = Cluster::new(ClusterConfig::local());
        let (big, small) = inputs();
        let (mut a, am) = broadcast_hash_join(&cluster, big.clone(), small.clone());
        let (mut b, bm) = sort_merge_join(&cluster, big, small);
        a.sort_unstable();
        b.sort_unstable();
        assert!(!a.is_empty());
        assert_eq!(a, b);
        assert_eq!(am.output_rows, bm.output_rows);
        assert!(am.total_sim_s() > 0.0);
        assert!(bm.total_sim_s() > 0.0);
    }
}
