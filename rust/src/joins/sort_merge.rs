//! Sort-merge join — Spark's default strategy for two large inputs, and
//! the final step of the paper's SBFCJ cascade (§5.2 step 5: "we let
//! Spark decide; for sufficiently large data it uses sort-merge join").
//!
//! Per reduce partition: TimSort both sides by key (the paper names
//! TimSort explicitly in its §7.1.2 cost analysis), then a two-pointer
//! merge that emits the cross product of equal-key groups.

use super::timsort::timsort_by_key;
use super::{JoinedRow, Keyed};

/// Join one co-partitioned bucket pair.  Inputs need not be sorted.
pub fn sort_merge_join_partition<B: Clone, S: Clone>(
    mut big: Vec<Keyed<B>>,
    mut small: Vec<Keyed<S>>,
) -> Vec<JoinedRow<B, S>> {
    timsort_by_key(&mut big, |r| r.0);
    timsort_by_key(&mut small, |r| r.0);
    merge_sorted(big, small)
}

/// Merge already-sorted sides (exposed for the pre-sorted fast path).
pub fn merge_sorted<B: Clone, S: Clone>(
    big: Vec<Keyed<B>>,
    small: Vec<Keyed<S>>,
) -> Vec<JoinedRow<B, S>> {
    let mut out = Vec::new();
    let mut i = 0;
    let mut j = 0;
    while i < big.len() && j < small.len() {
        let kb = big[i].0;
        let ks = small[j].0;
        if kb < ks {
            i += 1;
        } else if kb > ks {
            j += 1;
        } else {
            // equal-key groups: emit the cross product
            let i_end = big[i..].iter().take_while(|r| r.0 == kb).count() + i;
            let j_end = small[j..].iter().take_while(|r| r.0 == kb).count() + j;
            for bi in i..i_end {
                for sj in j..j_end {
                    out.push((kb, big[bi].1.clone(), small[sj].1.clone()));
                }
            }
            i = i_end;
            j = j_end;
        }
    }
    out
}

/// Comparison-count estimate for the model's `n log n` term: what the
/// per-partition sort costs at size `n` (used by DESIGN §model docs and
/// tests, not the hot path).
pub fn sort_cost_estimate(n: usize) -> f64 {
    if n < 2 {
        return n as f64;
    }
    let n = n as f64;
    n * n.log2()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::collections::HashMap;

    fn oracle<B: Clone, S: Clone>(
        big: &[Keyed<B>],
        small: &[Keyed<S>],
    ) -> Vec<JoinedRow<B, S>> {
        let mut out = Vec::new();
        for (kb, b) in big {
            for (ks, s) in small {
                if kb == ks {
                    out.push((*kb, b.clone(), s.clone()));
                }
            }
        }
        out
    }

    fn canon<B: Ord + Clone, S: Ord + Clone>(
        mut v: Vec<JoinedRow<B, S>>,
    ) -> Vec<JoinedRow<B, S>> {
        v.sort();
        v
    }

    #[test]
    fn matches_nested_loop_oracle() {
        let mut rng = Rng::new(5);
        for _ in 0..20 {
            let big: Vec<Keyed<u64>> =
                (0..rng.range(0, 200)).map(|_| (rng.below(50), rng.next_u64())).collect();
            let small: Vec<Keyed<u64>> =
                (0..rng.range(0, 60)).map(|_| (rng.below(50), rng.next_u64())).collect();
            let got = canon(sort_merge_join_partition(big.clone(), small.clone()));
            let want = canon(oracle(&big, &small));
            assert_eq!(got, want);
        }
    }

    #[test]
    fn duplicate_keys_cross_product() {
        let big = vec![(1u64, "b1"), (1, "b2"), (2, "b3")];
        let small = vec![(1u64, "s1"), (1, "s2")];
        let got = sort_merge_join_partition(big, small);
        assert_eq!(got.len(), 4);
        assert!(got.iter().all(|(k, _, _)| *k == 1));
    }

    #[test]
    fn disjoint_keys_empty() {
        let big = vec![(1u64, ()), (2, ())];
        let small = vec![(3u64, ()), (4, ())];
        assert!(sort_merge_join_partition(big, small).is_empty());
    }

    #[test]
    fn empty_sides() {
        assert!(sort_merge_join_partition::<(), ()>(vec![], vec![(1, ())]).is_empty());
        assert!(sort_merge_join_partition::<(), ()>(vec![(1, ())], vec![]).is_empty());
    }

    #[test]
    fn output_count_equals_key_multiplicity_product() {
        let mut rng = Rng::new(6);
        let big: Vec<Keyed<()>> = (0..500).map(|_| (rng.below(20), ())).collect();
        let small: Vec<Keyed<()>> = (0..100).map(|_| (rng.below(20), ())).collect();
        let mut bc: HashMap<u64, u64> = HashMap::new();
        let mut sc: HashMap<u64, u64> = HashMap::new();
        for (k, _) in &big {
            *bc.entry(*k).or_default() += 1;
        }
        for (k, _) in &small {
            *sc.entry(*k).or_default() += 1;
        }
        let want: u64 = bc.iter().map(|(k, nb)| nb * sc.get(k).copied().unwrap_or(0)).sum();
        assert_eq!(sort_merge_join_partition(big, small).len() as u64, want);
    }
}
