//! Join strategies: the paper's SBFCJ (bloom-filtered cascade join), its
//! two comparators — Spark's broadcast hash join (SBJ) and the plain
//! sort-merge join Spark defaults to for two large inputs — and the two
//! filter-shipping variants that scale past the broadcast wall: the
//! key-range-sharded partitioned bloom join and the two-round exchange
//! bloom join (`bloom_partitioned`).
//!
//! All of them operate on keyed, partitioned inputs and produce identical
//! result sets (property-tested against a nested-loop oracle in
//! `rust/tests/join_equivalence.rs`); what differs is the simulated
//! cluster cost, which is what the paper measures.

pub mod bloom_cascade;
pub mod bloom_partitioned;
pub mod broadcast_hash;
pub mod exec;
pub mod sort_merge;
pub mod timsort;

pub use bloom_cascade::{BloomCascadeConfig, BloomCascadeJoin, FilterBuildStyle, ProbePath};
pub use bloom_partitioned::{
    bloom_exchange_join, bloom_partitioned_join, bloom_partitioned_join_faulted, PartitionedAbort,
};
pub use exec::{broadcast_hash_join, sort_merge_join};
pub use sort_merge::sort_merge_join_partition;

/// A keyed row: the join key plus an opaque payload.
pub type Keyed<T> = (u64, T);

/// Join result row.
pub type JoinedRow<B, S> = (u64, B, S);

/// Estimate of per-row in-flight size for cost accounting, shared by the
/// strategies' shuffle/broadcast pricing.
pub trait RowSize {
    fn row_bytes(&self) -> u64;
}

impl RowSize for u64 {
    fn row_bytes(&self) -> u64 {
        8
    }
}

impl RowSize for u32 {
    fn row_bytes(&self) -> u64 {
        4
    }
}

impl RowSize for i64 {
    fn row_bytes(&self) -> u64 {
        8
    }
}

impl RowSize for i32 {
    fn row_bytes(&self) -> u64 {
        4
    }
}

impl RowSize for () {
    fn row_bytes(&self) -> u64 {
        0
    }
}

impl RowSize for crate::tpch::Order {
    fn row_bytes(&self) -> u64 {
        self.ser_bytes()
    }
}

impl RowSize for crate::tpch::Lineitem {
    fn row_bytes(&self) -> u64 {
        self.ser_bytes()
    }
}

impl<A: RowSize, B: RowSize> RowSize for (A, B) {
    fn row_bytes(&self) -> u64 {
        self.0.row_bytes() + self.1.row_bytes()
    }
}

impl RowSize for String {
    fn row_bytes(&self) -> u64 {
        self.len() as u64 + 4
    }
}
