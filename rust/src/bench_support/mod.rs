//! Bench harness (criterion is unavailable offline): warmup + timed
//! iterations with mean/p50/p95, markdown tables on stdout, and JSON rows
//! appended under `target/bench_results/` for EXPERIMENTS.md.

use std::time::Instant;

use crate::util::fmt::{duration, Table};
use crate::util::Json;

/// True when `BLOOMJOIN_BENCH_SMOKE=1` (or any non-`0` value): benches
/// shrink to seconds-scale shapes so CI can compile **and execute** every
/// bench target without the full experiment runtime.  Shapes change;
/// every bench's asserted invariants must hold in both modes.
pub fn smoke() -> bool {
    match std::env::var("BLOOMJOIN_BENCH_SMOKE") {
        Ok(v) => !v.is_empty() && v != "0",
        Err(_) => false,
    }
}

/// `full` normally, `small` under [`smoke`] — the one-liner benches use
/// to pick their shapes.
pub fn smoke_or<T>(small: T, full: T) -> T {
    if smoke() {
        small
    } else {
        full
    }
}

/// DESIGN §3 substitution rule: a small cluster whose per-byte channel
/// prices are scaled by the paper-SF / bench-SF ratio, so the data
/// economics (shuffle ≫ stage barriers ≫ filter shipping) match the
/// paper's SF-100 regime at an in-process data size.  Simulated seconds
/// are free.  Shared by the multi-way figure benches (fig5/fig6).
pub fn paper_scaled_cluster(sf: f64) -> crate::cluster::Cluster {
    let scale = 100.0 / sf;
    let mut cfg = crate::cluster::ClusterConfig::small_cluster();
    cfg.net_bandwidth /= scale;
    cfg.disk_bandwidth /= scale;
    crate::cluster::Cluster::new(cfg)
}

/// `base` with every edge's strategy replaced (plan shape preserved) —
/// how the figure benches force policy comparisons onto one planned tree.
/// Forced plans carry no dimension sketch features, so the adaptive
/// re-planner cannot undo the forced assignment.
pub fn forced_plan(
    base: &crate::plan::JoinPlan,
    strategies: Vec<crate::plan::EdgeStrategy>,
) -> crate::plan::JoinPlan {
    crate::plan::JoinPlan {
        topology: base.topology,
        edges: base
            .edges
            .iter()
            .zip(strategies)
            .map(|(e, s)| crate::plan::PlannedEdge::forced(e.relation, e.name.clone(), s))
            .collect(),
        dim_stats: Vec::new(),
    }
}

/// A calibration store whose fitted stage factors come out exactly
/// (α, β) — the poisoned prior `benches/fig9_regret.rs` and
/// `rust/tests/replan_trigger.rs` make the planner trust.
pub fn poisoned_store(alpha: f64, beta: f64) -> crate::plan::CostCalibration {
    let mut store = crate::plan::CostCalibration::default();
    for i in 0..4 {
        let p1 = 1.0 + i as f64;
        let p2 = 2.0 + 1.5 * i as f64;
        store.record(&crate::plan::EdgeObservation {
            edge: "seed".into(),
            relation: crate::plan::Relation::Orders,
            strategy: "bloom(eps=0.0500)".into(),
            eps: Some(0.05),
            resized: false,
            cached: false,
            recovered: false,
            estimated_probe_rows: 1,
            measured_probe_rows: 1,
            estimated_survivors: 1,
            measured_survivors: 1,
            build_wall_s: 0.0,
            probe_wall_s: 0.0,
            shipped_bytes: 0,
            sim_s: 0.0,
            measured_stage1_s: alpha * p1,
            measured_stage2_s: beta * p2,
            predicted_stage1_s: p1,
            predicted_stage2_s: p2,
        });
    }
    let (a, b) = store.factors().expect("poisoned store must fit");
    assert!((a - alpha).abs() < 1e-9 && (b - beta).abs() < 1e-9);
    store
}

/// Nested unique key sets: fact orderkeys are 1..=n each exactly once,
/// ORDERS covers 1..=o_keys of them, PART covers the whole partkey space
/// 1..=p_keys — every semijoin fraction is exact by construction, so
/// only constant error can mislead the planner.  Shared by the regret
/// bench and the trigger test suite.
pub fn exact_star_inputs(n: u64, o_keys: u64, p_keys: u64) -> crate::plan::PlanInputs {
    use crate::dataset::PartitionedTable;
    let lineitem: Vec<crate::plan::FactRow> = (0..n)
        .map(|i| crate::plan::FactRow {
            orderkey: i + 1,
            partkey: i % p_keys + 1,
            suppkey: i % 50 + 1,
            price_cents: i as i64,
        })
        .collect();
    let orders: Vec<(u64, u64, i32)> = (1..=o_keys).map(|ok| (ok, ok % 40 + 1, 5)).collect();
    let part: Vec<(u64, i32)> = (1..=p_keys).map(|pk| (pk, (pk % 25 + 1) as i32)).collect();
    crate::plan::PlanInputs {
        customer: PartitionedTable::from_rows(Vec::new(), 2),
        orders: PartitionedTable::from_rows(orders, 4),
        lineitem: PartitionedTable::from_rows(lineitem, 8),
        part: PartitionedTable::from_rows(part, 4),
        supplier: PartitionedTable::from_rows(Vec::new(), 2),
    }
}

/// One measured statistic set, seconds.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub min: f64,
    pub iters: usize,
}

/// Measure `f` with `iters` timed runs after `warmup` runs.
pub fn measure<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples: Vec<f64> = (0..iters.max(1))
        .map(|_| {
            let t0 = Instant::now();
            std::hint::black_box(f());
            t0.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    Stats {
        mean: samples.iter().sum::<f64>() / n as f64,
        p50: samples[n / 2],
        p95: samples[((n as f64 * 0.95) as usize).min(n - 1)],
        min: samples[0],
        iters: n,
    }
}

/// A bench report: named rows of (label, value columns).
pub struct Report {
    name: String,
    table: Table,
    json_rows: Vec<Json>,
    headers: Vec<String>,
}

impl Report {
    pub fn new(name: &str, headers: &[&str]) -> Self {
        println!("\n## bench: {name}\n");
        Report {
            name: name.to_string(),
            table: Table::new(headers),
            json_rows: vec![],
            headers: headers.iter().map(|s| s.to_string()).collect(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        let obj: Vec<(String, Json)> = self
            .headers
            .iter()
            .zip(&cells)
            .map(|(h, c)| {
                let v = c.parse::<f64>().map(Json::Num).unwrap_or_else(|_| Json::str(c.clone()));
                (h.clone(), v)
            })
            .collect();
        self.json_rows.push(Json::Obj(obj.into_iter().collect()));
        self.table.row(cells);
    }

    /// Print the table and persist JSON under target/bench_results/.
    pub fn finish(self) {
        println!("{}", self.table.render());
        let dir = std::path::Path::new("target/bench_results");
        let _ = std::fs::create_dir_all(dir);
        let payload = Json::obj([
            ("bench", Json::str(self.name.clone())),
            ("rows", Json::Arr(self.json_rows)),
        ]);
        let path = dir.join(format!("{}.json", self.name));
        if std::fs::write(&path, payload.to_string()).is_ok() {
            println!("(json: {})", path.display());
        }
    }
}

/// Write a `BENCH_<name>.json` trajectory point under
/// `target/bench_results/` — the one-object-per-PR series tracking
/// headline throughput numbers across the repo's history (CI uploads the
/// directory as a workflow artifact).
pub fn trajectory_point(name: &str, payload: Json) {
    let dir = std::path::Path::new("target/bench_results");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join(format!("BENCH_{name}.json"));
    if std::fs::write(&path, payload.to_string()).is_ok() {
        println!("(trajectory: {})", path.display());
    }
}

/// Format seconds for bench tables.
pub fn secs(s: f64) -> String {
    duration(std::time::Duration::from_secs_f64(s.max(0.0)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_returns_sane_stats() {
        let st = measure(1, 10, || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert_eq!(st.iters, 10);
        assert!(st.min <= st.p50 && st.p50 <= st.p95);
        assert!(st.mean > 0.0);
    }

    #[test]
    fn secs_formats() {
        assert!(secs(0.001).contains("ms"));
    }
}
