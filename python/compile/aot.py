"""AOT export: lower every L2 variant to HLO *text* + a JSON manifest.

HLO text (NOT ``lowered.compile()`` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which the xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/gen_hlo.py and README.md there.

Usage (from ``make artifacts``)::

    cd python && python -m compile.aot --out-dir ../artifacts

Python runs ONCE here; the Rust binary is self-contained afterwards.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import pathlib

import jax
from jax._src.lib import xla_client as xc

from .model import all_variants, example_args, fn_for
from .kernels.hashing import C1, C2, K_MAX
from .kernels.bloom_probe import BLOCK_KEYS


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_all(out_dir: pathlib.Path) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest: dict = {
        "format": "hlo-text/return-tuple-1",
        "hash": {"c1": C1, "c2": C2, "k_max": K_MAX, "scheme": "fmix32-double-hash"},
        "block_keys": BLOCK_KEYS,
        "variants": [],
    }
    for v in all_variants():
        lowered = jax.jit(fn_for(v)).lower(*example_args(v))
        text = to_hlo_text(lowered)
        path = out_dir / f"{v.name}.hlo.txt"
        path.write_text(text)
        manifest["variants"].append(
            {
                "name": v.name,
                "op": v.op,
                "log2_m": v.log2_m,
                "m_bits": v.m_bits,
                "n_words": v.n_words,
                "batch": v.batch,
                "file": path.name,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
                "params": (
                    ["keys:u32[B]", "words:u32[W]", "k:i32[1]"]
                    if v.op == "probe"
                    else ["keys:u32[B]", "k:i32[1]"]
                ),
            }
        )
        print(f"wrote {path} ({len(text)} chars)")
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {out_dir / 'manifest.json'} ({len(manifest['variants'])} variants)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    export_all(pathlib.Path(args.out_dir))


if __name__ == "__main__":
    main()
