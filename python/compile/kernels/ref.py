"""Pure-jnp oracle for the Bloom probe/build kernels.

No Pallas, no grids — just the hash algebra applied with dense jnp ops.
``python/tests/test_kernel.py`` asserts the Pallas kernel matches this
bit-for-bit across a hypothesis sweep of shapes, k values and filter sizes.
"""
from __future__ import annotations

import jax.numpy as jnp

from .hashing import K_MAX, probe_positions


def probe_ref(keys: jnp.ndarray, words: jnp.ndarray, k: jnp.ndarray, *, m_bits: int):
    """Reference membership probe; same contract as bloom_probe.probe."""
    pos = probe_positions(keys, m_bits)                    # (B, K_MAX)
    word_idx = (pos >> jnp.uint32(5)).astype(jnp.int32)
    bit = jnp.uint32(1) << (pos & jnp.uint32(31))
    hit = (words[word_idx] & bit) != jnp.uint32(0)
    j = jnp.arange(K_MAX, dtype=jnp.uint32)
    active = j < k[0].astype(jnp.uint32)
    return jnp.all(hit | ~active, axis=1).astype(jnp.int32)


def build_ref(keys: jnp.ndarray, k: jnp.ndarray, *, m_bits: int) -> jnp.ndarray:
    """Reference partial-filter build via an explicit per-key python loop —
    slow but obviously correct."""
    import numpy as np

    pos = np.asarray(probe_positions(keys, m_bits))
    kk = int(np.asarray(k)[0])
    words = np.zeros(m_bits // 32, dtype=np.uint32)
    for row in pos:
        for p in row[:kk]:
            words[int(p) >> 5] |= np.uint32(1) << np.uint32(int(p) & 31)
    return jnp.asarray(words)
