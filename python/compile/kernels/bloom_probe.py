"""L1 Pallas kernel: Bloom-filter membership probe over a key batch.

This is the compute hot-spot of the paper's SBFCJ algorithm: every record of
the big table is tested against the broadcast Bloom filter (paper §5.2 step
4).  The Rust coordinator streams big-table batches through the AOT-compiled
artifact of this kernel on the request path.

TPU-shaped design (DESIGN.md §Hardware-Adaptation):

* the filter word array is the *working set*: its BlockSpec maps the whole
  array on every grid step, so it is loaded to VMEM once and stays resident
  across the key stream (the analogue of Spark pinning the broadcast filter
  in the executor BlockManager).  The ladder caps W*4 bytes at 4 MiB,
  comfortably inside a 16 MiB VMEM budget together with the key block;
* keys stream through in blocks of ``BLOCK_KEYS`` along the grid dimension —
  the HBM->VMEM schedule that replaces Spark's per-row codegen loop;
* hashing is branch-free integer VPU work: two fmix32 mixes per key, then
  ``K_MAX`` fused gather+test lanes masked by ``j < k``.  Filter sizes are
  powers of two so ``mod m`` is a single bit-mask (no integer division);
* ``interpret=True`` always — real-TPU lowering emits a Mosaic custom call
  that the CPU PJRT plugin cannot execute.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .hashing import K_MAX, hash_pair

#: Keys per grid step.  8192-key batches (see model.py) split into 8 steps.
BLOCK_KEYS = 1024


def _probe_kernel(k_ref, keys_ref, words_ref, mask_ref, *, m_bits: int):
    """One grid step: test BLOCK_KEYS keys against the resident filter.

    k_ref     : i32[1]   — number of active hash functions (1..K_MAX)
    keys_ref  : u32[BLOCK_KEYS]
    words_ref : u32[W]   — packed filter bits, bit p lives at word p>>5,
                           bit position p&31
    mask_ref  : i32[BLOCK_KEYS] out — 1 iff all k probed bits are set
    """
    keys = keys_ref[...]
    k = k_ref[0]
    h1, h2 = hash_pair(keys)
    j = jax.lax.broadcasted_iota(jnp.uint32, (keys.shape[0], K_MAX), 1)
    pos = (h1[:, None] + j * h2[:, None]) & jnp.uint32(m_bits - 1)
    word_idx = (pos >> jnp.uint32(5)).astype(jnp.int32)
    bit = jnp.uint32(1) << (pos & jnp.uint32(31))
    words = words_ref[...]
    hit = (words[word_idx] & bit) != jnp.uint32(0)
    active = j < k.astype(jnp.uint32)
    mask_ref[...] = jnp.all(hit | ~active, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("m_bits",))
def probe(keys: jnp.ndarray, words: jnp.ndarray, k: jnp.ndarray, *, m_bits: int):
    """Batched Bloom probe.

    keys : u32[B] with B a multiple of BLOCK_KEYS (the Rust side pads);
    words: u32[m_bits // 32];
    k    : i32[1] active hash count;
    returns i32[B] membership mask (1 = possibly in the small table).
    """
    batch, = keys.shape
    assert batch % BLOCK_KEYS == 0, f"batch {batch} not a multiple of {BLOCK_KEYS}"
    n_words = m_bits // 32
    assert words.shape == (n_words,)
    grid = (batch // BLOCK_KEYS,)
    return pl.pallas_call(
        functools.partial(_probe_kernel, m_bits=m_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),            # k: resident scalar
            pl.BlockSpec((BLOCK_KEYS,), lambda i: (i,)),   # keys: streamed
            pl.BlockSpec((n_words,), lambda i: (0,)),      # words: resident
        ],
        out_specs=pl.BlockSpec((BLOCK_KEYS,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((batch,), jnp.int32),
        interpret=True,
    )(k, keys, words)
