"""Bloom-filter kernels: L1 Pallas probe, jnp build, pure-jnp oracle."""

from .bloom_build import build
from .bloom_probe import BLOCK_KEYS, probe
from .hashing import C1, C2, K_MAX, fold64_py, probe_positions, probe_positions_py
from .ref import build_ref, probe_ref

__all__ = [
    "BLOCK_KEYS",
    "C1",
    "C2",
    "K_MAX",
    "build",
    "build_ref",
    "fold64_py",
    "probe",
    "probe_positions",
    "probe_positions_py",
    "probe_ref",
]
