"""Shared Bloom-filter hash algebra.

This module is the single source of truth for the hash scheme used by the
Pallas probe kernel, the jnp build graph, the pure-jnp reference oracle and
(re-implemented identically, checked by golden vectors) the Rust native
filter in ``rust/src/bloom/hash.rs``.

Scheme
------
Keys arrive as ``uint32`` (the Rust side folds 64-bit join keys with
splitmix64 before handing them to the kernel).  We derive two independent
32-bit hashes with murmur3's ``fmix32`` finalizer under distinct xor salts,
force the second one odd, and use classic double hashing

    pos_j = (h1 + j * h2) mod m        for j in 0..k

with ``m`` a power of two so the ``mod`` is a bit-mask and the odd stride
``h2`` is a unit of Z/mZ (every probe sequence is a full cycle, no
clustering on the pow-2 lattice).

All arithmetic is wrapping uint32 — identical semantics in numpy/jnp and
Rust ``u32``.
"""
from __future__ import annotations

import jax.numpy as jnp

# Salts for the two hash streams (golden-ratio / murmur constants).
C1 = 0x9E3779B9
C2 = 0x85EBCA77

#: Upper bound on the number of hash functions any artifact supports.  The
#: optimal k for the smallest sensible error rate we sweep (1e-4) is
#: ceil(log2(1/1e-4)) = 14, so 16 leaves headroom and keeps the probe loop
#: shape static.
K_MAX = 16


def mix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 fmix32 finalizer — a full-avalanche 32-bit permutation."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def hash_pair(keys: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Return the double-hash pair ``(h1, h2)`` with ``h2`` forced odd."""
    keys = keys.astype(jnp.uint32)
    h1 = mix32(keys ^ jnp.uint32(C1))
    h2 = mix32(keys ^ jnp.uint32(C2)) | jnp.uint32(1)
    return h1, h2


def probe_positions(keys: jnp.ndarray, m_bits: int) -> jnp.ndarray:
    """All ``K_MAX`` candidate bit positions for each key.

    Returns shape ``keys.shape + (K_MAX,)`` uint32, each in ``[0, m_bits)``.
    ``m_bits`` must be a power of two.
    """
    assert m_bits & (m_bits - 1) == 0, "filter size must be a power of two"
    h1, h2 = hash_pair(keys)
    j = jnp.arange(K_MAX, dtype=jnp.uint32)
    pos = h1[..., None] + j * h2[..., None]
    return pos & jnp.uint32(m_bits - 1)


# --- pure-python mirror (int arithmetic), used for golden vectors ---------

def _mix32_py(x: int) -> int:
    x &= 0xFFFFFFFF
    x ^= x >> 16
    x = (x * 0x85EBCA6B) & 0xFFFFFFFF
    x ^= x >> 13
    x = (x * 0xC2B2AE35) & 0xFFFFFFFF
    x ^= x >> 16
    return x


def probe_positions_py(key: int, m_bits: int, k: int) -> list[int]:
    """Pure-python reference of ``probe_positions`` for one key."""
    h1 = _mix32_py((key ^ C1) & 0xFFFFFFFF)
    h2 = _mix32_py((key ^ C2) & 0xFFFFFFFF) | 1
    return [((h1 + j * h2) & 0xFFFFFFFF) & (m_bits - 1) for j in range(k)]


def splitmix64_py(x: int) -> int:
    """splitmix64 finalizer; the Rust side folds u64 keys to u32 with
    ``(splitmix64(key) >> 32) as u32`` before calling any kernel."""
    x = (x + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return z ^ (z >> 31)


def fold64_py(key: int) -> int:
    return splitmix64_py(key) >> 32


def wide64_py(key: int) -> int:
    """Packed 64-bit hash word for quotienting structures: the double-hash
    pair of the folded key with ``h1`` in the high word and the odd ``h2``
    low.  Mirrors ``wide64`` in ``rust/src/bloom/hash.rs`` (used by the
    Pagh filter), pinned by ``tests/test_golden.py::GOLDEN_WIDE64``."""
    kf = fold64_py(key)
    h1 = _mix32_py((kf ^ C1) & 0xFFFFFFFF)
    h2 = _mix32_py((kf ^ C2) & 0xFFFFFFFF) | 1
    return (h1 << 32) | h2
