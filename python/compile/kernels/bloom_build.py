"""L2 graph: distributed Bloom-filter partial build for one partition batch.

Paper §5.1 change #1: the filter is *not* built single-pass on the driver —
each partition builds a partial filter over its own keys and the partials
are merged by bitwise OR (a Bloom filter algebra identity).  The Rust
coordinator runs this graph per partition batch and ORs the resulting word
arrays; merging is associative/commutative so the merge tree shape is free.

Build is one-time per query (not the request-path hot spot), so it is a
plain jnp scatter rather than a Pallas kernel: scatter-max into an m-bit
boolean vector, then pack 32 bits/word.  Padded slots in the last batch are
filled by the Rust side with a *repeat of a real key*, which is idempotent
under OR (sets no extra bits).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .hashing import K_MAX, probe_positions


@functools.partial(jax.jit, static_argnames=("m_bits",))
def build(keys: jnp.ndarray, k: jnp.ndarray, *, m_bits: int) -> jnp.ndarray:
    """Partial filter for one key batch.

    keys : u32[B]; k : i32[1]; returns u32[m_bits // 32] packed words.
    """
    pos = probe_positions(keys, m_bits)                    # (B, K_MAX)
    j = jnp.arange(K_MAX, dtype=jnp.uint32)
    active = (j < k[0].astype(jnp.uint32))                 # (K_MAX,)
    active = jnp.broadcast_to(active, pos.shape)
    bits = jnp.zeros((m_bits,), dtype=jnp.bool_)
    # scatter-max: inactive lanes write False onto False — a no-op.
    bits = bits.at[pos.reshape(-1)].max(active.reshape(-1))
    shifts = jnp.arange(32, dtype=jnp.uint32)
    words = (bits.reshape(m_bits // 32, 32).astype(jnp.uint32) << shifts).sum(
        axis=1, dtype=jnp.uint32
    )
    return words
