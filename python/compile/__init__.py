"""Build-time compile package: Pallas/jnp kernels + AOT export."""
