"""L2: the query's compute graph, one jitted function per artifact variant.

The paper's hot spot is step 4 of SBFCJ — probing every big-table record
against the broadcast Bloom filter — plus the per-partition partial-filter
build of step 2/3.  Both are expressed here as jax functions over *static*
shapes drawn from a filter-size ladder (AOT compilation requires static
shapes; DESIGN.md §6 explains the pow-2 ladder and its ε distortion).

``aot.py`` lowers each variant once to HLO text; the Rust runtime compiles
each artifact once per process and executes it on the request path.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from .kernels.bloom_build import build as _build
from .kernels.bloom_probe import BLOCK_KEYS, probe as _probe
from .kernels.hashing import K_MAX

#: Keys per request-path batch.  A multiple of the kernel's BLOCK_KEYS; the
#: Rust side pads the final partial batch (padding for probe is discarded by
#: slicing the mask; padding for build repeats a real key).
BATCH_KEYS = 8192

#: Filter-size ladder, in log2(bits).  2^17 = 128 Kbit (16 KiB) up to
#: 2^25 = 32 Mbit (4 MiB of u32 words — resident-working-set budget, see
#: DESIGN.md §Hardware-Adaptation).  Rust rounds the cost model's optimal m
#: up to the next rung.
PROBE_LADDER = (17, 19, 21, 23, 25)

#: Build artifacts scatter an m-bit dense vector, so cap the lowered
#: variants at 2^23 bits; larger filters fall back to the Rust native
#: builder (bit-identical by the golden-vector tests).
BUILD_LADDER = (17, 19, 21, 23)


@dataclass(frozen=True)
class Variant:
    """One AOT artifact: an op specialised to a filter rung."""

    op: str            # "probe" | "build"
    log2_m: int        # filter size in bits = 2**log2_m
    batch: int = BATCH_KEYS

    @property
    def m_bits(self) -> int:
        return 1 << self.log2_m

    @property
    def n_words(self) -> int:
        return self.m_bits // 32

    @property
    def name(self) -> str:
        return f"{self.op}_m{self.log2_m}_b{self.batch}"


def probe_fn(variant: Variant):
    """probe(keys u32[B], words u32[W], k i32[1]) -> i32[B]."""

    def fn(keys, words, k):
        return (_probe(keys, words, k, m_bits=variant.m_bits),)

    return fn


def build_fn(variant: Variant):
    """build(keys u32[B], k i32[1]) -> u32[W]."""

    def fn(keys, k):
        return (_build(keys, k, m_bits=variant.m_bits),)

    return fn


def example_args(variant: Variant):
    """ShapeDtypeStructs used to lower the variant."""
    import jax

    keys = jax.ShapeDtypeStruct((variant.batch,), jnp.uint32)
    k = jax.ShapeDtypeStruct((1,), jnp.int32)
    if variant.op == "probe":
        words = jax.ShapeDtypeStruct((variant.n_words,), jnp.uint32)
        return (keys, words, k)
    return (keys, k)


def all_variants() -> list[Variant]:
    out = [Variant("probe", lm) for lm in PROBE_LADDER]
    out += [Variant("build", lm) for lm in BUILD_LADDER]
    return out


def fn_for(variant: Variant):
    return probe_fn(variant) if variant.op == "probe" else build_fn(variant)
