"""`scripts/bench_trajectory.py` must survive the states the committed
series files actually pass through: absent, seeded empty (`[]`), one
point deep, schema-drifted, or hand-mangled — the gate degrades to
"no gate", never crashes the bench-smoke job."""
from __future__ import annotations

import importlib.util
import json
import os

import pytest

_SCRIPT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", "scripts", "bench_trajectory.py")
)
_spec = importlib.util.spec_from_file_location("bench_trajectory", _SCRIPT)
bt = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bt)


def write_fresh_points(results_dir):
    """One fresh point per tracked bench, with every metric's inputs."""
    os.makedirs(results_dir, exist_ok=True)
    payloads = {
        "fig7_throughput": {"batched_keys_per_s": 300.0, "scalar_keys_per_s": 100.0},
        "fig8_adaptive": {"missed_static_s": 8.0, "missed_adaptive_s": 4.0},
        "fig9_regret": {"mispriced_static_s": 6.0, "mispriced_regret_s": 3.0},
        "fig10_partitioned": {"broadcast_bytes": 4096.0, "partitioned_bytes": 1024.0},
        "fig11_server": {"cold_p50_ms": 20.0, "warm_p50_ms": 5.0},
        "fig12_faults": {"clean_sim_s": 2.0, "chaos_sim_s": 4.0},
        "fig13_fused": {"edge_sim_s": 3.0, "fused_sim_s": 1.5},
        "fig14_graph": {"dp_sim_s": 1.0, "greedy_sim_s": 1.8},
    }
    assert set(payloads) == set(bt.TRACKED), "keep the test's fresh points in sync"
    for name, payload in payloads.items():
        with open(os.path.join(results_dir, f"BENCH_{name}.json"), "w") as f:
            json.dump(payload, f)
    return payloads


def seed(repo_root, name, content):
    with open(os.path.join(repo_root, f"BENCH_{name}.json"), "w") as f:
        f.write(content)


def test_gate_passes_with_no_committed_series(tmp_path, capsys):
    results = tmp_path / "results"
    write_fresh_points(results)
    bt.gate(str(results), str(tmp_path))
    out = capsys.readouterr().out
    assert out.count("first point — no gate") == len(bt.TRACKED)


def test_gate_passes_with_seeded_empty_series(tmp_path, capsys):
    results = tmp_path / "results"
    write_fresh_points(results)
    for name in bt.TRACKED:
        seed(tmp_path, name, "[]\n")
    bt.gate(str(results), str(tmp_path))
    assert "no gate" in capsys.readouterr().out


def test_load_series_tolerates_mangled_files(tmp_path):
    seed(tmp_path, "fig10_partitioned", "")
    assert bt.load_series(str(tmp_path), "fig10_partitioned") == []
    seed(tmp_path, "fig10_partitioned", "{not json")
    assert bt.load_series(str(tmp_path), "fig10_partitioned") == []
    seed(tmp_path, "fig10_partitioned", '{"a": 1}')
    assert bt.load_series(str(tmp_path), "fig10_partitioned") == []


def test_gate_compares_against_a_one_point_series(tmp_path, capsys):
    results = tmp_path / "results"
    fresh = write_fresh_points(results)
    for name in bt.TRACKED:
        seed(tmp_path, name, json.dumps([fresh[name]]))
    bt.gate(str(results), str(tmp_path))  # identical metric: passes
    assert capsys.readouterr().out.count("OK") == len(bt.TRACKED)


def test_gate_fails_on_regression_past_threshold(tmp_path):
    results = tmp_path / "results"
    write_fresh_points(results)
    better = {"broadcast_bytes": 4096.0, "partitioned_bytes": 512.0}  # ratio 8 vs fresh 4
    for name in bt.TRACKED:
        seed(tmp_path, name, "[]")
    seed(tmp_path, "fig10_partitioned", json.dumps([better]))
    with pytest.raises(SystemExit):
        bt.gate(str(results), str(tmp_path))


def test_gate_skips_points_predating_the_metric(tmp_path, capsys):
    results = tmp_path / "results"
    write_fresh_points(results)
    for name in bt.TRACKED:
        seed(tmp_path, name, json.dumps([{"commit": "abc", "legacy_field": 1}]))
    bt.gate(str(results), str(tmp_path))
    assert capsys.readouterr().out.count("predates metric — no gate") == len(bt.TRACKED)


def test_append_seeds_and_extends_series(tmp_path, monkeypatch):
    results = tmp_path / "results"
    write_fresh_points(results)
    seed(tmp_path, "fig10_partitioned", "[]\n")
    monkeypatch.setenv("GITHUB_SHA", "deadbeef")
    bt.append(str(results), str(tmp_path))
    series = bt.load_series(str(tmp_path), "fig10_partitioned")
    assert len(series) == 1 and series[0]["commit"] == "deadbeef"
    # re-running the job with the same trigger SHA must not double-append
    bt.append(str(results), str(tmp_path))
    assert len(bt.load_series(str(tmp_path), "fig10_partitioned")) == 1
