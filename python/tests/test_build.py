"""Bloom build graph: vs loop oracle, OR-merge algebra, FPR behaviour."""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import BLOCK_KEYS, K_MAX, build, build_ref, probe


def _keys(rng, n):
    return jnp.asarray(rng.integers(0, 2**32, size=n, dtype=np.uint64).astype(np.uint32))


@pytest.mark.parametrize("log2_m", [17, 19])
@pytest.mark.parametrize("k", [1, 5, K_MAX])
def test_build_matches_ref(log2_m: int, k: int) -> None:
    rng = np.random.default_rng(42 + log2_m + k)
    keys = _keys(rng, 512)
    kk = jnp.asarray([k], jnp.int32)
    got = np.asarray(build(keys, kk, m_bits=1 << log2_m))
    want = np.asarray(build_ref(keys, kk, m_bits=1 << log2_m))
    assert np.array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(k=st.integers(1, K_MAX), seed=st.integers(0, 2**31 - 1))
def test_or_merge_equals_bulk_build(k: int, seed: int) -> None:
    """Partial build + OR == one-shot build over the union (paper §5.1 #1)."""
    rng = np.random.default_rng(seed)
    m_bits = 1 << 17
    a, b = _keys(rng, 300), _keys(rng, 200)
    kk = jnp.asarray([k], jnp.int32)
    merged = np.asarray(build(a, kk, m_bits=m_bits)) | np.asarray(build(b, kk, m_bits=m_bits))
    bulk = np.asarray(build(jnp.concatenate([a, b]), kk, m_bits=m_bits))
    assert np.array_equal(merged, bulk)


def test_duplicate_keys_idempotent() -> None:
    """Pad-by-repeating-a-real-key sets no extra bits."""
    rng = np.random.default_rng(9)
    m_bits = 1 << 17
    keys = np.asarray(_keys(rng, 100))
    kk = jnp.asarray([7], jnp.int32)
    once = np.asarray(build(jnp.asarray(keys), kk, m_bits=m_bits))
    padded = np.concatenate([keys, np.repeat(keys[-1], 156)])
    twice = np.asarray(build(jnp.asarray(padded), kk, m_bits=m_bits))
    assert np.array_equal(once, twice)


def test_no_false_negatives_and_fpr_near_epsilon() -> None:
    """End-to-end build+probe: members always pass; FPR tracks the optimal-
    filter prediction (1 - e^{-kn/m})^k within a loose statistical band."""
    rng = np.random.default_rng(11)
    m_bits = 1 << 17                     # m = 131072 bits
    n = 8192                             # bits/key = 16 -> with k=11, fpr ~ 4.6e-4
    k = 11
    member = np.asarray(_keys(rng, n))
    kk = jnp.asarray([k], jnp.int32)
    words = build(jnp.asarray(member), kk, m_bits=m_bits)

    got_members = np.asarray(probe(jnp.asarray(member), words, kk, m_bits=m_bits))
    assert np.all(got_members == 1), "bloom filters must never false-negative"

    probe_n = 4 * BLOCK_KEYS
    others = np.asarray(_keys(rng, probe_n))  # collisions with `member` negligible
    got = np.asarray(probe(jnp.asarray(others), words, kk, m_bits=m_bits))
    fpr = got.mean()
    predicted = (1 - np.exp(-k * n / m_bits)) ** k
    assert fpr <= max(5 * predicted, 0.003), f"fpr {fpr} vs predicted {predicted}"


def test_build_empty_k_zero_lanes() -> None:
    """k=1 on a single key sets exactly <=1 distinct bit per key."""
    m_bits = 1 << 17
    words = np.asarray(build(jnp.asarray([123], jnp.uint32), jnp.asarray([1], jnp.int32), m_bits=m_bits))
    assert int(sum(bin(w).count("1") for w in words)) == 1
