"""AOT export sanity: every variant lowers to parseable HLO text with the
expected parameter shapes, and the manifest indexes all of them."""
from __future__ import annotations

import json
import pathlib

import pytest

from compile.aot import export_all, to_hlo_text
from compile.model import BATCH_KEYS, Variant, all_variants, example_args, fn_for


def test_variant_names_unique() -> None:
    names = [v.name for v in all_variants()]
    assert len(names) == len(set(names))


def test_variant_shapes() -> None:
    v = Variant("probe", 17)
    assert v.m_bits == 1 << 17
    assert v.n_words == (1 << 17) // 32
    assert v.batch == BATCH_KEYS


@pytest.mark.parametrize("v", [Variant("probe", 17), Variant("build", 17)])
def test_lower_to_hlo_text(v: Variant) -> None:
    import jax

    lowered = jax.jit(fn_for(v)).lower(*example_args(v))
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # parameters of the ENTRY computation: keys (+words) + k
    n_params = 3 if v.op == "probe" else 2
    lines = text.splitlines()
    start = next(i for i, line in enumerate(lines) if line.startswith("ENTRY"))
    entry_body = []
    for line in lines[start + 1 :]:
        if line.strip() == "}":
            break
        entry_body.append(line)
    assert sum(" parameter(" in line for line in entry_body) == n_params


def test_export_all_manifest(tmp_path: pathlib.Path) -> None:
    manifest = export_all(tmp_path)
    files = {p.name for p in tmp_path.iterdir()}
    assert "manifest.json" in files
    for entry in manifest["variants"]:
        assert entry["file"] in files
        assert (tmp_path / entry["file"]).stat().st_size > 0
    on_disk = json.loads((tmp_path / "manifest.json").read_text())
    assert on_disk["variants"] == manifest["variants"]
    assert on_disk["hash"]["scheme"] == "fmix32-double-hash"
