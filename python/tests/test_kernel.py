"""Pallas probe kernel vs pure-jnp oracle — the core correctness signal.

hypothesis sweeps shapes (batch counts), k values and filter-ladder sizes;
every case must match the oracle bit-for-bit (integer outputs, so equality,
with assert_allclose as the final guard).
"""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

import jax.numpy as jnp

from compile.kernels import BLOCK_KEYS, K_MAX, build, build_ref, probe, probe_ref
from compile.kernels.hashing import probe_positions, probe_positions_py


def _rand_keys(rng: np.random.Generator, n: int) -> jnp.ndarray:
    return jnp.asarray(rng.integers(0, 2**32, size=n, dtype=np.uint64).astype(np.uint32))


def _filter_for(keys: np.ndarray, m_bits: int, k: int) -> jnp.ndarray:
    """Build a filter with the jnp builder (itself tested vs build_ref)."""
    pad = (-len(keys)) % BLOCK_KEYS
    padded = np.concatenate([keys, np.repeat(keys[-1], pad)]) if pad else keys
    return build(jnp.asarray(padded), jnp.asarray([k], jnp.int32), m_bits=m_bits)


@pytest.mark.parametrize("log2_m", [17, 19, 21])
@pytest.mark.parametrize("k", [1, 7, K_MAX])
def test_probe_matches_ref(log2_m: int, k: int) -> None:
    rng = np.random.default_rng(log2_m * 100 + k)
    m_bits = 1 << log2_m
    member = np.asarray(_rand_keys(rng, 3 * BLOCK_KEYS))
    words = _filter_for(member, m_bits, k)
    queries = jnp.concatenate(
        [jnp.asarray(member[:BLOCK_KEYS]), _rand_keys(rng, 3 * BLOCK_KEYS)]
    )
    kk = jnp.asarray([k], jnp.int32)
    got = probe(queries, words, kk, m_bits=m_bits)
    want = probe_ref(queries, words, kk, m_bits=m_bits)
    assert_allclose(np.asarray(got), np.asarray(want))
    # zero false negatives: every member key must pass
    assert np.all(np.asarray(got)[:BLOCK_KEYS] == 1)


@settings(max_examples=20, deadline=None)
@given(
    n_blocks=st.integers(1, 4),
    k=st.integers(1, K_MAX),
    log2_m=st.sampled_from([17, 19, 21]),
    seed=st.integers(0, 2**31 - 1),
)
def test_probe_hypothesis_sweep(n_blocks: int, k: int, log2_m: int, seed: int) -> None:
    rng = np.random.default_rng(seed)
    m_bits = 1 << log2_m
    keys = _rand_keys(rng, n_blocks * BLOCK_KEYS)
    words = jnp.asarray(rng.integers(0, 2**32, size=m_bits // 32, dtype=np.uint64).astype(np.uint32))
    kk = jnp.asarray([k], jnp.int32)
    got = probe(keys, words, kk, m_bits=m_bits)
    want = probe_ref(keys, words, kk, m_bits=m_bits)
    assert got.shape == (n_blocks * BLOCK_KEYS,)
    assert got.dtype == jnp.int32
    assert_allclose(np.asarray(got), np.asarray(want))


def test_probe_k_monotone() -> None:
    """More hash functions can only make the probe stricter."""
    rng = np.random.default_rng(7)
    m_bits = 1 << 17
    keys = _rand_keys(rng, BLOCK_KEYS)
    words = jnp.asarray(rng.integers(0, 2**32, size=m_bits // 32, dtype=np.uint64).astype(np.uint32))
    prev = np.ones(BLOCK_KEYS, dtype=np.int32)
    for k in range(1, K_MAX + 1):
        cur = np.asarray(probe(keys, words, jnp.asarray([k], jnp.int32), m_bits=m_bits))
        assert np.all(cur <= prev)
        prev = cur


def test_positions_match_pure_python() -> None:
    """jnp hash algebra == pure-python ints (the Rust golden source)."""
    keys = np.array([0, 1, 42, 0xDEADBEEF, 2**32 - 1], dtype=np.uint32)
    m_bits = 1 << 19
    pos = np.asarray(probe_positions(jnp.asarray(keys), m_bits))
    for i, key in enumerate(keys):
        assert list(pos[i]) == probe_positions_py(int(key), m_bits, K_MAX)


def test_probe_all_ones_filter_accepts_everything() -> None:
    m_bits = 1 << 17
    keys = _rand_keys(np.random.default_rng(3), BLOCK_KEYS)
    words = jnp.full((m_bits // 32,), 0xFFFFFFFF, dtype=jnp.uint32)
    got = probe(keys, words, jnp.asarray([K_MAX], jnp.int32), m_bits=m_bits)
    assert np.all(np.asarray(got) == 1)


def test_probe_empty_filter_rejects_everything() -> None:
    m_bits = 1 << 17
    keys = _rand_keys(np.random.default_rng(4), BLOCK_KEYS)
    words = jnp.zeros((m_bits // 32,), dtype=jnp.uint32)
    got = probe(keys, words, jnp.asarray([1], jnp.int32), m_bits=m_bits)
    assert np.all(np.asarray(got) == 0)
