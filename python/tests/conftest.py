"""Test-suite wiring: put `python/` on sys.path so `compile.*` imports
resolve when the suite runs as `python -m pytest python/tests` from the
repo root, and skip modules whose optional dependencies (hypothesis, jax)
are absent — the golden-vector tests are the cross-language drift guard
and must stay runnable on a bare interpreter + jax."""
from __future__ import annotations

import importlib.util
import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

collect_ignore = []
if importlib.util.find_spec("hypothesis") is None:
    collect_ignore += ["test_kernel.py", "test_build.py"]
if importlib.util.find_spec("jax") is None:
    collect_ignore = ["test_kernel.py", "test_build.py", "test_aot.py", "test_golden.py"]
