"""Golden vectors shared with the Rust native implementation.

``rust/src/bloom/hash.rs`` hardcodes the same table; if either side changes
the hash algebra, both this test and the Rust unit test fail.  Regenerate
with::

    cd python && python -m tests.test_golden
"""
from __future__ import annotations

from compile.kernels.hashing import fold64_py, probe_positions_py, wide64_py

# (key_u32, m_bits, k) -> positions
GOLDEN_POSITIONS = {
    (0, 1 << 17, 4): [12046, 81955, 20792, 90701],
    (1, 1 << 17, 4): [46339, 24664, 2989, 112386],
    (42, 1 << 19, 6): [126672, 304003, 481334, 134377, 311708, 489039],
    (0xDEADBEEF, 1 << 21, 8): [
        965299, 1919236, 776021, 1729958, 586743, 1540680, 397465, 1351402,
    ],
    (0xFFFFFFFF, 1 << 25, 3): [23507626, 1190431, 12427668],
}

# key_u64 -> fold64(key) (splitmix64 >> 32)
GOLDEN_FOLD64 = {
    0: 0xE220A839,
    1: 0x910A2DEC,
    6000000: 0x810BE29C,
    0xFFFFFFFFFFFFFFFF: 0xE4D97177,
}


# key_u64 -> wide64(key): (h1 << 32) | (h2 | 1) of the folded key — the
# shared quotienting hash (Pagh filter) and the word memoized per lane by
# the fused pipeline's hash cache; rust/src/bloom/hash.rs pins the same
# table in golden_wide64_match_python and rust/src/bloom/batch.rs pins it
# through HashedChunk (hashed_chunk_golden_wide64_match_python), so the
# memoized chunk path can never silently diverge from the scalar probe.
# 7/63/64 pin the chunk-lane boundaries, 123456789 a mid-range key.
GOLDEN_WIDE64 = {
    0: 0x6E7B9CBBFC9FF8FF,
    1: 0xDC725748FE6AB465,
    7: 0x0FB02A5BFE1052F1,
    42: 0x2119E8C3B6ED9779,
    63: 0x6CB97E822DDA3137,
    64: 0x6CB73CCD65856AC5,
    6000000: 0xA76AAA86A693F51F,
    123456789: 0xADC55054570A4885,
    0xDEADBEEF: 0xA613392890A569E1,
    0xFFFFFFFFFFFFFFFF: 0x16F2A371CDF4283B,
}


def test_probe_positions_golden() -> None:
    for (key, m_bits, k), want in GOLDEN_POSITIONS.items():
        assert probe_positions_py(key, m_bits, k) == want, (key, m_bits, k)


def test_fold64_golden() -> None:
    for key, want in GOLDEN_FOLD64.items():
        assert fold64_py(key) == want, hex(key)


def test_wide64_golden() -> None:
    for key, want in GOLDEN_WIDE64.items():
        assert wide64_py(key) == want, hex(key)
        assert wide64_py(key) & 1 == 1, "low word must be the odd h2"


if __name__ == "__main__":
    for (key, m_bits, k) in GOLDEN_POSITIONS:
        print((key, m_bits, k), probe_positions_py(key, m_bits, k))
    for key in GOLDEN_FOLD64:
        print(hex(key), hex(fold64_py(key)))
    for key in GOLDEN_WIDE64:
        print(hex(key), hex(wide64_py(key)))
