#!/usr/bin/env python3
"""Maintain the in-repo bench trajectory series.

Each tracked bench writes a one-object `BENCH_<name>.json` point under
`target/bench_results/` per run (see `bench_support::trajectory_point`).
The repo root holds the cross-PR series: `BENCH_<name>.json` as a JSON
array, one appended object per landed PR, committed by the bench-smoke
job on pushes to main.

Subcommands (both take <bench_results_dir> <repo_root>):

  gate    compare the fresh point's headline metric against the last
          committed point; exit non-zero on a >20% regression.  A missing
          or empty committed series passes (first point).
  append  append the fresh point (stamped with GITHUB_SHA when set) to
          the committed series files.
"""
import json
import os
import sys

TRACKED = {
    # the batched/scalar ratio, not absolute keys/sec: both numbers come
    # from the same runner, so the ratio survives heterogeneous shared CI
    # hardware while still catching vectorization regressions
    "fig7_throughput": (
        "batched/scalar speedup",
        lambda p: p["batched_keys_per_s"] / max(p["scalar_keys_per_s"], 1e-9),
    ),
    "fig8_adaptive": (
        "adaptive win ratio (hot-keys-missed static/adaptive)",
        lambda p: p["missed_static_s"] / max(p["missed_adaptive_s"], 1e-9),
    ),
    "fig9_regret": (
        "regret win ratio (mispriced-tail static/regret)",
        lambda p: p["mispriced_static_s"] / max(p["mispriced_regret_s"], 1e-9),
    ),
    # filter-ship bytes are simulated, not timed, so the ratio is exact
    # and deterministic: broadcast's executors×filter bill over the
    # partitioned strategy's route+shard-ship bill at the largest shape
    "fig10_partitioned": (
        "partitioned ship win ratio (broadcast/partitioned bytes)",
        lambda p: p["broadcast_bytes"] / max(p["partitioned_bytes"], 1e-9),
    ),
    # warm-over-cold p50 for the repeated 5-relation star through the
    # server's filter+plan caches — a ratio of two timings from the same
    # runner, like fig7
    "fig11_server": (
        "server cache win ratio (cold/warm p50)",
        lambda p: p["cold_p50_ms"] / max(p["warm_p50_ms"], 1e-9),
    ),
    # fault-recovery efficiency: the clean plan's simulated total over the
    # same plan under the chaos profile.  Both totals are simulated, so
    # the ratio is exact and deterministic; it falls (trips the gate) when
    # surviving faults gets more expensive relative to the clean run
    "fig12_faults": (
        "fault recovery efficiency (clean/chaos sim)",
        lambda p: p["clean_sim_s"] / max(p["chaos_sim_s"], 1e-9),
    ),
    # fused-probe win: the same forced all-bloom 5-relation star run
    # edge-at-a-time and fused.  Both totals are simulated, so the ratio
    # is exact; it falls when the fused pipeline loses its one-scan /
    # hash-once advantage over per-edge stream passes
    "fig13_fused": (
        "fused probe win ratio (edge/fused sim)",
        lambda p: p["edge_sim_s"] / max(p["fused_sim_s"], 1e-9),
    ),
    # graph-planner win: the branched acyclic shape planned by the
    # bottom-up enumeration vs the greedy-legacy order, both executed
    # through the same bloom full reducer.  Both totals are simulated, so
    # the ratio is exact; it falls when the joint strategy/ε/order choice
    # stops paying for itself on non-star shapes
    "fig14_graph": (
        "graph planner win ratio (greedy/DP sim)",
        lambda p: p["greedy_sim_s"] / max(p["dp_sim_s"], 1e-9),
    ),
}
# fail when a metric drops below this fraction of the last committed point
THRESHOLD = 0.8


def fresh_point(results_dir, name):
    with open(os.path.join(results_dir, f"BENCH_{name}.json")) as f:
        return json.load(f)


def series_path(repo_root, name):
    return os.path.join(repo_root, f"BENCH_{name}.json")


def load_series(repo_root, name):
    """The committed series, or [] for anything unusable.

    Newly tracked benches are seeded as an empty array (or not at all),
    and a botched manual edit must degrade to "first point — no gate"
    rather than crash the whole bench-smoke job.
    """
    path = series_path(repo_root, name)
    if not os.path.exists(path):
        return []
    with open(path) as f:
        text = f.read().strip()
    if not text:
        return []
    try:
        data = json.loads(text)
    except json.JSONDecodeError:
        print(f"{name}: committed series is not valid JSON — treating as empty")
        return []
    if not isinstance(data, list):
        print(f"{name}: committed series is not a JSON array — treating as empty")
        return []
    return data


def gate(results_dir, repo_root):
    failed = False
    for name, (label, metric) in TRACKED.items():
        now = metric(fresh_point(results_dir, name))
        series = load_series(repo_root, name)
        if not series:
            print(f"{name}: {label} = {now:.3f} (first point — no gate)")
            continue
        try:
            prev = metric(series[-1])
        except (KeyError, TypeError):
            # a committed point from before this metric's fields existed
            print(f"{name}: {label} = {now:.3f} (last point predates metric — no gate)")
            continue
        ok = now >= THRESHOLD * prev
        verdict = "OK" if ok else f"REGRESSION (below {THRESHOLD:.0%} of previous)"
        print(f"{name}: {label} = {now:.3f} vs committed {prev:.3f} — {verdict}")
        failed |= not ok
    if failed:
        sys.exit(1)


def append(results_dir, repo_root):
    sha = os.environ.get("GITHUB_SHA", "")
    for name in TRACKED:
        series = load_series(repo_root, name)
        # job re-runs rebase onto the bot commit they pushed last time —
        # don't append the same trigger SHA's point twice
        if sha and series and isinstance(series[-1], dict) and series[-1].get("commit") == sha:
            print(f"{name}: point for {sha[:12]} already committed — skipping")
            continue
        point = fresh_point(results_dir, name)
        if sha:
            point = {"commit": sha, **point}
        series.append(point)
        with open(series_path(repo_root, name), "w") as f:
            json.dump(series, f, indent=1)
            f.write("\n")
        print(f"{name}: appended point #{len(series)}")


def main():
    if len(sys.argv) != 4 or sys.argv[1] not in ("gate", "append"):
        print("usage: bench_trajectory.py gate|append <bench_results_dir> <repo_root>")
        sys.exit(2)
    (gate if sys.argv[1] == "gate" else append)(sys.argv[2], sys.argv[3])


if __name__ == "__main__":
    main()
